"""Golden-run regression scenarios for the streaming analytics engine.

Tiny deterministic sweeps (ring + star topologies, single- and multi-
source OOD placement) whose in-scan analytics — per-node IID/OOD
accuracy-AUC, arrival rounds, gap — are checked into
``tests/goldens/sweep_analytics.json`` and asserted to tolerance by
``tests/test_golden.py``.  This is the repo's first golden-value suite:
Palmieri et al.'s topology-dependent propagation curves are exactly where
reproductions silently drift, so the numbers themselves are pinned, not
just the code paths.

Regenerate after an INTENTIONAL numerical change (new jax/XLA pin, a
deliberate algorithm change):

    PYTHONPATH=src python -m tests.regen_goldens

``compute_goldens`` also cross-checks the streaming values against the
host-side ``repro.core.propagation`` oracles to 1e-6 on every run, so a
regenerated golden can never encode a streaming/oracle divergence.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import propagation
from repro.core.analytics import AnalyticsSpec
from repro.core.decentralized import (
    DecentralizedConfig,
    coeffs_stack,
    stack_params,
)
from repro.core.strategies import AggregationStrategy
from repro.core.sweep import SweepEngine
from repro.core.topology import Topology, ring, star
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "goldens")
GOLDEN_PATH = os.path.join(GOLDEN_DIR, "sweep_analytics.json")

N = 6
ROUNDS = 6
EVAL_EVERY = 2
THRESHOLD = 0.5
BATCH = 8
TOL = 1e-5  # AUC / accuracy tolerance; arrival rounds are exact ints


def scenarios() -> List[Tuple[str, Topology, str, Tuple[int, ...]]]:
    """(name, topology, strategy, OOD source nodes) — one sweep-engine
    experiment each, all n=6 so the grid compiles into ONE program."""
    return [
        ("ring6/unweighted/src0", ring(N), "unweighted", (0,)),
        # ring degrees are uniform, so "degree" would equal "unweighted";
        # "random" instead locks the per-round resampling stream
        ("ring6/random/src0", ring(N), "random", (0,)),
        ("star6/degree/leaf3", star(N), "degree", (3,)),
        ("star6/unweighted/hub0+leaf3", star(N), "unweighted", (0, 3)),
    ]


def _pad_cap(bank: Dict[str, np.ndarray], cap: int) -> Dict[str, np.ndarray]:
    return {
        k: np.pad(v, [(0, 0), (0, cap - v.shape[1])]
                  + [(0, 0)] * (v.ndim - 2))
        for k, v in bank.items()
    }


def build_engine_inputs(scens=None):
    """The scenario grid as one set of SweepEngine inputs (E=4, D=3
    distinct data configurations keyed by OOD source tuple).  ``scens``
    overrides the default :func:`scenarios` grid with another list of
    ``(name, topology, strategy, sources)`` cells at the same scale
    (the participation suite reuses this builder)."""
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)
    from repro.training.optimizer import sgd

    if scens is None:
        scens = scenarios()
    train = make_dataset("mnist", 360, seed=0)
    test = make_dataset("mnist", 96, seed=9)
    cfg = DecentralizedConfig(rounds=ROUNDS, local_epochs=2,
                              eval_every=EVAL_EVERY)

    dconf: Dict[Tuple[int, ...], int] = {}
    batchers: List[NodeBatcher] = []
    for _, _, _, srcs in scens:
        if srcs not in dconf:
            parts = node_datasets(train, N, ood_node=srcs, q=0.10, seed=0)
            dconf[srcs] = len(batchers)
            batchers.append(NodeBatcher(parts, batch_size=BATCH,
                                        steps_per_epoch=2, seed=0,
                                        local_epochs=cfg.local_epochs))
    raw = [nb.sample_bank() for nb in batchers]
    cap = max(b["x"].shape[1] for b in raw)
    padded = [_pad_cap(b, cap) for b in raw]
    bank = {k: np.stack([p[k] for p in padded]) for k in raw[0]}
    indices = np.stack([nb.all_round_indices(ROUNDS) for nb in batchers])

    data_idx, coeffs, p0s = [], [], []
    init = ffn_init(jax.random.key(0))
    for _, topo, strat, srcs in scens:
        d = dconf[srcs]
        data_idx.append(d)
        coeffs.append(coeffs_stack(
            topo, AggregationStrategy(strat, tau=0.1, seed=0), ROUNDS,
            data_counts=batchers[d].data_counts()))
        p0s.append(stack_params([init] * N))
    params0 = jax.tree.map(lambda *xs: jnp.stack(xs), *p0s)

    tb = make_test_batch(test, 48, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 48, seed=0)
    e = len(scens)
    stack_e = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * e) for k in t}

    engine = SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                         classifier_accuracy(ffn_apply), cfg)
    args = (params0, np.stack(coeffs), bank, indices,
            np.asarray(data_idx, np.int32), stack_e(tb), stack_e(ob))
    return engine, args


def compute_goldens(mesh=None, chunk_rounds: Optional[int] = None,
                    keep_history: bool = True) -> Dict:
    """Run the scenario grid and digest it into the golden payload.

    With ``keep_history=True`` (default) every scenario's streaming
    analytics are asserted against the host-side ``propagation.py``
    oracles to 1e-6 before anything is returned."""
    engine, args = build_engine_inputs()
    res = engine.run(*args, batch_size=BATCH, mesh=mesh,
                     chunk_rounds=chunk_rounds,
                     analytics=AnalyticsSpec(arrival_threshold=THRESHOLD),
                     keep_history=keep_history)
    out: Dict = {
        "meta": {"n_nodes": N, "rounds": ROUNDS, "eval_every": EVAL_EVERY,
                 "arrival_threshold": THRESHOLD, "batch": BATCH},
        "scenarios": {},
    }
    for e, (name, topo, _, srcs) in enumerate(scenarios()):
        stream = {k: v[e] for k, v in res.analytics.items()}
        if keep_history:
            hist = res.history(e)
            dev = max(
                np.abs(stream["iid_auc"]
                       - propagation.per_node_auc(hist, "iid")).max(),
                np.abs(stream["ood_auc"]
                       - propagation.per_node_auc(hist, "ood")).max())
            assert dev < 1e-6, (name, dev)
            oracle_arrival = propagation.arrival_rounds(hist, THRESHOLD)
            np.testing.assert_array_equal(stream["ood_arrival"],
                                          oracle_arrival, err_msg=name)
        hops = propagation.hops_from(topo.adjacency, srcs)
        out["scenarios"][name] = {
            "ood_sources": list(srcs),
            "hops_from_sources": [int(h) for h in hops],
            "iid_auc": [float(v) for v in stream["iid_auc"]],
            "ood_auc": [float(v) for v in stream["ood_auc"]],
            "ood_arrival": [int(v) for v in stream["ood_arrival"]],
            "iid_ood_gap_pct": float(
                100.0 * (stream["ood_auc"].mean()
                         - stream["iid_auc"].mean())
                / max(float(stream["iid_auc"].mean()), 1e-9)),
            "final_ood_acc_mean": float(stream["final_ood_acc"].mean()),
        }
    return out


# ----------------------------------------------------------------------
# edge-list path golden suite: an n=256 BA sweep through mix_impl="edges"
# ----------------------------------------------------------------------
EDGES_GOLDEN_PATH = os.path.join(GOLDEN_DIR, "sweep_analytics_edges.json")
EDGES_N = 256
EDGES_ROUNDS = 3


def edges_topology() -> Topology:
    from repro.core.topology import barabasi_albert

    return barabasi_albert(EDGES_N, p=2, seed=0)


def edges_scenarios() -> List[Tuple[str, Topology, str, Tuple[int, ...]]]:
    """Single-source OOD at the two degree extremes of one n=256 BA graph
    — the hub-vs-periphery placement contrast the paper's propagation
    curves hinge on, run entirely on the padded-ELL edge-list mix."""
    topo = edges_topology()
    hub = topo.kth_highest_degree_node(1)
    leaf = int(topo.nodes_by_degree()[-1])
    return [
        ("ba256/degree/src-max-degree", topo, "degree", (hub,)),
        ("ba256/degree/src-min-degree", topo, "degree", (leaf,)),
    ]


def build_edges_engine_inputs():
    """The edges scenario grid as one set of SweepEngine inputs (E=2,
    D=2 data configurations; hidden=32 FFN keeps the n=256 plane small)."""
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)
    from repro.training.optimizer import sgd

    train = make_dataset("mnist", 2560, seed=0)
    test = make_dataset("mnist", 96, seed=9)
    cfg = DecentralizedConfig(rounds=EDGES_ROUNDS, local_epochs=1,
                              eval_every=1, mix_impl="edges")

    scens = edges_scenarios()
    topo = scens[0][1]
    batchers: List[NodeBatcher] = []
    for _, _, _, srcs in scens:
        parts = node_datasets(train, EDGES_N, ood_node=srcs, q=0.10, seed=0)
        batchers.append(NodeBatcher(parts, batch_size=BATCH,
                                    steps_per_epoch=2, seed=0,
                                    local_epochs=cfg.local_epochs))
    raw = [nb.sample_bank() for nb in batchers]
    cap = max(b["x"].shape[1] for b in raw)
    padded = [_pad_cap(b, cap) for b in raw]
    bank = {k: np.stack([p[k] for p in padded]) for k in raw[0]}
    indices = np.stack([nb.all_round_indices(EDGES_ROUNDS)
                        for nb in batchers])

    init = ffn_init(jax.random.key(0), hidden=32)
    coeffs = np.stack([
        np.asarray(coeffs_stack(
            topo, AggregationStrategy(strat, tau=0.1, seed=0), EDGES_ROUNDS,
            data_counts=batchers[d].data_counts()))
        for d, (_, _, strat, _) in enumerate(scens)])
    p0 = stack_params([init] * EDGES_N)
    params0 = jax.tree.map(lambda *xs: jnp.stack(xs), *([p0] * len(scens)))

    tb = make_test_batch(test, 48, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 48, seed=0)
    stack_e = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * len(scens))
                         for k in t}

    engine = SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                         classifier_accuracy(ffn_apply), cfg,
                         mix_support=topo.adjacency + np.eye(EDGES_N))
    args = (params0, coeffs, bank, indices,
            np.arange(len(scens), dtype=np.int32), stack_e(tb), stack_e(ob))
    return engine, args


def compute_edges_goldens(mesh=None, chunk_rounds: Optional[int] = None,
                          keep_history: bool = True) -> Dict:
    """Run the edges grid and digest it into the golden payload — same
    shape (and same streaming/oracle cross-check) as the dense suite."""
    engine, args = build_edges_engine_inputs()
    res = engine.run(*args, batch_size=BATCH, mesh=mesh,
                     chunk_rounds=chunk_rounds,
                     analytics=AnalyticsSpec(arrival_threshold=THRESHOLD),
                     keep_history=keep_history)
    scens = edges_scenarios()
    out: Dict = {
        "meta": {"n_nodes": EDGES_N, "rounds": EDGES_ROUNDS, "eval_every": 1,
                 "arrival_threshold": THRESHOLD, "batch": BATCH,
                 "mix_impl": "edges",
                 "max_degree": scens[0][1].max_degree()},
        "scenarios": {},
    }
    for e, (name, topo, _, srcs) in enumerate(scens):
        stream = {k: v[e] for k, v in res.analytics.items()}
        if keep_history:
            hist = res.history(e)
            dev = max(
                np.abs(stream["iid_auc"]
                       - propagation.per_node_auc(hist, "iid")).max(),
                np.abs(stream["ood_auc"]
                       - propagation.per_node_auc(hist, "ood")).max())
            assert dev < 1e-6, (name, dev)
        hops = propagation.hops_from(topo.adjacency, srcs)
        out["scenarios"][name] = {
            "ood_sources": list(srcs),
            "max_hops_from_sources": int(max(hops)),
            "src_ood_auc": float(stream["ood_auc"][srcs[0]]),
            "iid_auc_mean": float(stream["iid_auc"].mean()),
            "ood_auc_mean": float(stream["ood_auc"].mean()),
            "ood_arrival_mean": float(
                np.asarray(stream["ood_arrival"], np.float64).mean()),
            "iid_ood_gap_pct": float(
                100.0 * (stream["ood_auc"].mean()
                         - stream["iid_auc"].mean())
                / max(float(stream["iid_auc"].mean()), 1e-9)),
            "final_ood_acc_mean": float(stream["final_ood_acc"].mean()),
        }
    return out


# ----------------------------------------------------------------------
# partial-participation golden suite (DESIGN.md §15): staleness counters,
# time-skewed local steps, and the staleness × arrival interaction on one
# ring and one BA topology, pinned per rate
# ----------------------------------------------------------------------
PARTICIPATION_GOLDEN_PATH = os.path.join(GOLDEN_DIR,
                                         "sweep_participation.json")


def participation_scenarios():
    """(name, topology, strategy, OOD sources, participation rate) — the
    rate-1.0 ring cell doubles as the synchronous bit-identity control
    (asserted inside :func:`compute_participation_goldens`)."""
    from repro.core.topology import barabasi_albert

    ba = barabasi_albert(N, 2, seed=0)
    hub = ba.kth_highest_degree_node(1)
    return [
        ("ring6/unweighted/src0/r1.0", ring(N), "unweighted", (0,), 1.0),
        ("ring6/unweighted/src0/r0.5", ring(N), "unweighted", (0,), 0.5),
        ("ba6/degree/hub/r0.5", ba, "degree", (hub,), 0.5),
        ("ba6/degree/hub/r0.25", ba, "degree", (hub,), 0.25),
    ]


def compute_participation_goldens(mesh=None,
                                  chunk_rounds: Optional[int] = None,
                                  keep_history: bool = True) -> Dict:
    """Run the participation grid (one compiled program; the rates ride
    the vmap axis) and digest it into the golden payload.

    On the primary call (no mesh/chunking, history kept) the rate-1.0
    scenario is additionally asserted BIT-identical to the synchronous
    engine on the same inputs — a regenerated golden can never encode a
    drifted all-active path."""
    from repro.core.analytics import participation_summary
    from repro.core.dynamic import ParticipationSpec

    pscens = participation_scenarios()
    engine, args = build_engine_inputs(scens=[s[:4] for s in pscens])
    rates = np.asarray([s[4] for s in pscens], np.float32)
    spec = ParticipationSpec()  # bernoulli, stale-plane mixing, seed 0
    res = engine.run(*args, batch_size=BATCH, mesh=mesh,
                     chunk_rounds=chunk_rounds,
                     analytics=AnalyticsSpec(arrival_threshold=THRESHOLD),
                     keep_history=keep_history,
                     participation=spec, participation_rates=rates)
    if mesh is None and chunk_rounds is None and keep_history:
        sync = engine.run(*args, batch_size=BATCH,
                          analytics=AnalyticsSpec(
                              arrival_threshold=THRESHOLD))
        e1 = [i for i, s in enumerate(pscens) if s[4] == 1.0]
        for e in e1:
            np.testing.assert_array_equal(res.train_loss[e],
                                          sync.train_loss[e])
            np.testing.assert_array_equal(res.iid_acc[e], sync.iid_acc[e])
            np.testing.assert_array_equal(res.ood_acc[e], sync.ood_acc[e])
            for k in sync.analytics:
                np.testing.assert_array_equal(res.analytics[k][e],
                                              sync.analytics[k][e])
    out: Dict = {
        "meta": {"n_nodes": N, "rounds": ROUNDS, "eval_every": EVAL_EVERY,
                 "arrival_threshold": THRESHOLD, "batch": BATCH,
                 "participation_mode": spec.mode,
                 "stale_mixing": spec.stale_mixing,
                 "participation_seed": spec.seed},
        "scenarios": {},
    }
    for e, (name, topo, _, srcs, rate) in enumerate(pscens):
        part = {k: v[e] for k, v in res.participation.items()}
        stream = {k: v[e] for k, v in res.analytics.items()}
        digest = participation_summary(part, ROUNDS, stream)
        out["scenarios"][name] = {
            "rate": rate,
            "ood_sources": list(srcs),
            "rounds_active": [int(v) for v in part["rounds_active"]],
            "final_staleness": [int(v) for v in part["final_staleness"]],
            "mean_staleness": [float(v) for v in part["mean_staleness"]],
            "local_steps": [int(v) for v in part["local_steps"]],
            "ood_arrival": [int(v) for v in stream["ood_arrival"]],
            "ood_auc_mean": float(stream["ood_auc"].mean()),
            "activity_rate": digest["activity_rate"],
            "staleness_arrival_corr": digest["staleness_arrival_corr"],
        }
    return out


# ----------------------------------------------------------------------
# byzantine robustness golden suite (DESIGN.md §16): signflip faults at a
# pinned rate grid on ring + BA, aggregated by plain mean (the vulnerable
# baseline), trimmed mean, median, and mean + self-healing quarantine —
# the headline robust-vs-mean OOD numbers, pinned per aggregator
# ----------------------------------------------------------------------
BYZANTINE_GOLDEN_PATH = os.path.join(GOLDEN_DIR, "sweep_byzantine.json")
BYZ_SCALE = 12.0  # amplified enough that the norm screen (×10) trips


def byzantine_scenarios():
    """(name, topology, strategy, OOD sources, fault rate) — the
    rate-0.0 ring cell doubles as the synchronous bit-identity control
    (asserted inside :func:`compute_byzantine_goldens`); the BA cells
    contrast hub vs leaf OOD placement under the same fault stream."""
    from repro.core.topology import barabasi_albert

    ba = barabasi_albert(N, 2, seed=0)
    hub = ba.kth_highest_degree_node(1)
    leaf = int(ba.nodes_by_degree()[-1])
    return [
        ("ring6/unweighted/src0/f0.0", ring(N), "unweighted", (0,), 0.0),
        ("ring6/unweighted/src0/f0.2", ring(N), "unweighted", (0,), 0.2),
        ("ba6/degree/hub/f0.2", ba, "degree", (hub,), 0.2),
        ("ba6/degree/leaf/f0.35", ba, "degree", (leaf,), 0.35),
    ]


def compute_byzantine_goldens(mesh=None, chunk_rounds: Optional[int] = None,
                              keep_history: bool = True) -> Dict:
    """Run the byzantine grid once per aggregator (one compiled program
    each; the fault rates ride the vmap axis) and digest it into the
    golden payload.

    On the primary call (no mesh/chunking, history kept) the rate-0.0
    scenario of the plain-mean run is additionally asserted BIT-identical
    to the fault-free synchronous engine on the same inputs — a
    regenerated golden can never encode a drifted fault-free path.  The
    realized fault draw (``fault_rounds``) is asserted identical across
    aggregators on every run: the corruption stream is a pinned PRNG
    function of (seed, round), never of what the aggregator did with it.
    """
    from repro.core.analytics import quarantine_summary
    from repro.core.dynamic import FaultSpec
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply)
    from repro.training.optimizer import sgd

    bscens = byzantine_scenarios()
    engine, args = build_engine_inputs(scens=[s[:4] for s in bscens])
    rates = np.asarray([s[4] for s in bscens], np.float32)
    support = np.eye(N)
    for _, topo, _, _ in (s[:4] for s in bscens):
        support = np.maximum(support, np.asarray(topo.adjacency))
    spec = FaultSpec(mode="signflip", byz_scale=BYZ_SCALE)
    qspec = FaultSpec(mode="signflip", byz_scale=BYZ_SCALE,
                      quarantine=True, probation=2)

    def robust_engine(robust):
        cfg = DecentralizedConfig(rounds=ROUNDS, local_epochs=2,
                                  eval_every=EVAL_EVERY, robust=robust)
        return SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                           classifier_accuracy(ffn_apply), cfg,
                           mix_support=support)

    run = lambda en, fs: en.run(
        *args, batch_size=BATCH, mesh=mesh, chunk_rounds=chunk_rounds,
        analytics=AnalyticsSpec(arrival_threshold=THRESHOLD),
        keep_history=keep_history, fault=fs, fault_rates=rates)
    results = {
        "mean": run(engine, spec),
        "trimmed": run(robust_engine("trimmed"), spec),
        "median": run(robust_engine("median"), spec),
        "mean+quarantine": run(engine, qspec),
    }
    base = results["mean"]
    for agg, res in results.items():
        np.testing.assert_array_equal(
            res.fault["fault_rounds"], base.fault["fault_rounds"],
            err_msg=f"fault draw diverged under {agg}")
    if mesh is None and chunk_rounds is None and keep_history:
        sync = engine.run(*args, batch_size=BATCH,
                          analytics=AnalyticsSpec(
                              arrival_threshold=THRESHOLD))
        e0 = [i for i, s in enumerate(bscens) if s[4] == 0.0]
        for e in e0:
            np.testing.assert_array_equal(base.train_loss[e],
                                          sync.train_loss[e])
            np.testing.assert_array_equal(base.iid_acc[e], sync.iid_acc[e])
            np.testing.assert_array_equal(base.ood_acc[e], sync.ood_acc[e])
            for k in sync.analytics:
                np.testing.assert_array_equal(base.analytics[k][e],
                                              sync.analytics[k][e])
        # the robustness claim the suite exists to pin: under every
        # nonzero fault rate the robust aggregators END UP at least as
        # accurate on the OOD task as plain mean (AUC can lag — trimming
        # also slows early propagation — but recovery must not)
        for e, s in enumerate(bscens):
            if s[4] == 0.0:
                continue
            fm = float(base.analytics["final_ood_acc"][e].mean())
            for agg in ("trimmed", "median"):
                fr = float(
                    results[agg].analytics["final_ood_acc"][e].mean())
                assert fr >= fm - 1e-6, (s[0], agg, fr, fm)
    out: Dict = {
        "meta": {"n_nodes": N, "rounds": ROUNDS, "eval_every": EVAL_EVERY,
                 "arrival_threshold": THRESHOLD, "batch": BATCH,
                 "fault_mode": spec.mode, "byz_scale": BYZ_SCALE,
                 "fault_seed": spec.seed, "robust_trim": 1,
                 "quarantine_probation": qspec.probation,
                 "quarantine_spike_ratio": qspec.spike_ratio},
        "scenarios": {},
    }
    for e, (name, topo, _, srcs, rate) in enumerate(bscens):
        fdig = {k: v[e] for k, v in base.fault.items()}
        cell: Dict = {
            "fault_rate": rate,
            "ood_sources": list(srcs),
            "fault_rounds": [int(v) for v in fdig["fault_rounds"]],
            "first_fault": [int(v) for v in fdig["first_fault"]],
            "aggregators": {},
        }
        for agg, res in results.items():
            stream = {k: v[e] for k, v in res.analytics.items()}
            cell["aggregators"][agg] = {
                "iid_auc_mean": float(stream["iid_auc"].mean()),
                "ood_auc_mean": float(stream["ood_auc"].mean()),
                "ood_arrival": [int(v) for v in stream["ood_arrival"]],
                "final_ood_acc_mean": float(stream["final_ood_acc"].mean()),
            }
        q = quarantine_summary(
            {k: v[e] for k, v in results["mean+quarantine"].fault.items()},
            ROUNDS)
        cell["quarantine"] = {
            "n_faulty_nodes": q["n_faulty_nodes"],
            "fault_round_rate": q["fault_round_rate"],
            "rounds_quarantined_mean": q["rounds_quarantined_mean"],
            "detection_lag_mean": q["detection_lag_mean"],
            "n_undetected": q["n_undetected"],
            "false_positive_rate": q["false_positive_rate"],
        }
        out["scenarios"][name] = cell
    return out


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    goldens = compute_goldens()
    with open(GOLDEN_PATH, "w") as f:
        json.dump(goldens, f, indent=1)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")
    for name, g in goldens["scenarios"].items():
        print(f"  {name}: ood_auc_mean={np.mean(g['ood_auc']):.4f} "
              f"arrival={g['ood_arrival']}")
    edges = compute_edges_goldens()
    with open(EDGES_GOLDEN_PATH, "w") as f:
        json.dump(edges, f, indent=1)
        f.write("\n")
    print(f"wrote {EDGES_GOLDEN_PATH}")
    for name, g in edges["scenarios"].items():
        print(f"  {name}: ood_auc_mean={g['ood_auc_mean']:.4f} "
              f"arrival_mean={g['ood_arrival_mean']:.2f}")
    part = compute_participation_goldens()
    with open(PARTICIPATION_GOLDEN_PATH, "w") as f:
        json.dump(part, f, indent=1)
        f.write("\n")
    print(f"wrote {PARTICIPATION_GOLDEN_PATH}")
    for name, g in part["scenarios"].items():
        print(f"  {name}: ood_auc_mean={g['ood_auc_mean']:.4f} "
              f"activity={g['activity_rate']:.2f} "
              f"staleness={np.mean(g['mean_staleness']):.2f}")
    byz = compute_byzantine_goldens()
    with open(BYZANTINE_GOLDEN_PATH, "w") as f:
        json.dump(byz, f, indent=1)
        f.write("\n")
    print(f"wrote {BYZANTINE_GOLDEN_PATH}")
    for name, g in byz["scenarios"].items():
        aucs = " ".join(f"{a}={v['ood_auc_mean']:.4f}"
                        for a, v in g["aggregators"].items())
        print(f"  {name}: rate={g['fault_rate']} {aucs}")


if __name__ == "__main__":
    main()
