"""Dense ↔ sparse mix equivalence harness (the edge-list path's proof).

One shared fixture per topology family builds a tiny multi-leaf MLP sweep
(E = one experiment per strategy) and runs it through ``SweepEngine`` with
``mix_impl="einsum"`` as the reference.  Every other backend — the fused
dense plane kernel, the circulant host-sparse path, and the padded-ELL
edge-list kernel — must reproduce that reference on the SAME inputs, and
the edge-list path must additionally be bit-identical to itself across
every execution mode (scanned / chunked / mesh-sharded / unrolled).

The ``slow``-marked test scales the same harness to an n=1024 BA graph —
the regime the edge-list path exists for (dmax ≪ n) — and is excluded
from the default run (``pytest -m slow`` opts in).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coeffs import ProgramCoeffs, program_for, stack_states
from repro.core.decentralized import (
    DecentralizedConfig,
    coeffs_stack,
    stack_params,
)
from repro.core.strategies import AggregationStrategy
from repro.core.sweep import SweepEngine
from repro.core.topology import (
    barabasi_albert,
    ring,
    stochastic_block,
    watts_strogatz,
)
from repro.training.optimizer import sgd
from tests.test_sweep import _eval_fn, _loss_fn, _mlp_init

N, ROUNDS, CAP, S, BATCH = 8, 4, 12, 4, 2
STRATEGIES = ("unweighted", "degree", "random")
FAMILIES = {
    "ring": lambda: ring(N),
    "ba": lambda: barabasi_albert(N, p=2, seed=0),
    "ws": lambda: watts_strogatz(N, k=4, u=0.3, seed=0),
    "sb": lambda: stochastic_block(N, n_communities=2, seed=0),
}


def _cfg(mix_impl="einsum"):
    # epoch_shuffle=False: the hand-built (1, R, n, S) index schedule IS
    # the batch order; sparse_slack=N lets the circulant path cover any
    # family's support without a dense fallback.
    return DecentralizedConfig(rounds=ROUNDS, local_epochs=1, eval_every=2,
                               epoch_shuffle=False, mix_impl=mix_impl,
                               sparse_slack=N)


def _engine_inputs(n=N, n_exp=len(STRATEGIES), seed=0):
    rng = np.random.default_rng(seed)
    bank = {
        "x": jnp.asarray(rng.normal(size=(1, n, CAP, 5)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(1, n, CAP, 2)), jnp.float32),
    }
    indices = rng.integers(0, CAP, size=(1, ROUNDS, n, S)).astype(np.int32)
    data_idx = np.zeros(n_exp, np.int32)
    stack_e = lambda b: jax.tree.map(
        lambda l: jnp.broadcast_to(l, (n_exp,) + l.shape), b)
    tb = stack_e({"x": jnp.asarray(rng.normal(size=(16, 5)), jnp.float32),
                  "y": jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)})
    ob = stack_e({"x": jnp.asarray(rng.normal(size=(16, 5)), jnp.float32),
                  "y": jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)})
    params0 = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (n_exp,) + l.shape),
        stack_params([_mlp_init(0)] * n))
    return params0, bank, indices, data_idx, tb, ob


def _run(topo, mix_impl, coeffs=None, n_exp=len(STRATEGIES), **run_kw):
    params0, bank, indices, data_idx, tb, ob = _engine_inputs(
        n=topo.n_nodes, n_exp=n_exp)
    if coeffs is None:
        coeffs = np.stack([
            np.asarray(coeffs_stack(
                topo, AggregationStrategy(k, tau=0.1, seed=0), ROUNDS))
            for k in STRATEGIES[:n_exp]])
    support = topo.adjacency + np.eye(topo.n_nodes)
    engine = SweepEngine(
        sgd(1e-2), _loss_fn, _eval_fn, _cfg(mix_impl),
        mix_support=None if mix_impl == "einsum" else support)
    return engine.run(params0, coeffs, bank, indices, data_idx, tb, ob,
                      batch_size=BATCH, **run_kw)


def _assert_results_close(a, b, rtol=1e-5, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)
    np.testing.assert_allclose(a.train_loss, b.train_loss,
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(a.iid_acc, b.iid_acc, rtol=rtol, atol=atol)
    np.testing.assert_allclose(a.ood_acc, b.ood_acc, rtol=rtol, atol=atol)


def _assert_results_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
    np.testing.assert_array_equal(a.ood_acc, b.ood_acc)


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    topo = FAMILIES[request.param]()
    return topo, _run(topo, "einsum")


@pytest.mark.parametrize("impl", ["pallas", "sparse", "edges"])
def test_impl_matches_einsum(family, impl):
    """Every mix backend reproduces the dense einsum reference on every
    topology family × strategy (unweighted / degree / random slabs)."""
    topo, ref = family
    _assert_results_close(_run(topo, impl), ref)


def test_edges_modes_bitexact(family):
    """The edge-list path is ONE traced round function — scanned, chunked,
    mesh-sharded and unrolled execution must agree bit-for-bit."""
    from repro.launch.mesh import make_sweep_mesh

    topo, _ = family
    scanned = _run(topo, "edges")
    _assert_results_equal(_run(topo, "edges", chunk_rounds=2), scanned)
    _assert_results_equal(_run(topo, "edges", mesh=make_sweep_mesh(1)),
                          scanned)
    _assert_results_equal(_run(topo, "edges", unroll_eval=True), scanned)


def test_edges_program_coeffs_matches_einsum(family):
    """Device-side coefficient programs (link failure + reactive degree)
    drive the edge-list mix exactly like the materialized slab drives the
    dense one."""
    topo, _ = family
    ps = [program_for(topo, AggregationStrategy("degree", tau=0.1, seed=s),
                      p_fail=0.3, reactive=True) for s in (0, 1)]
    pc = ProgramCoeffs(ps[0][0], stack_states([s for _, s in ps]))
    slab = np.stack([p.materialize(s, rounds=ROUNDS) for p, s in ps])
    ref = _run(topo, "einsum", coeffs=slab, n_exp=2)
    out = _run(topo, "edges", coeffs=pc, n_exp=2)
    _assert_results_close(out, ref)


@pytest.mark.slow
def test_edges_at_n1024_matches_einsum():
    """The scaling claim, run end-to-end: an n=1024 BA sweep through the
    standard scanned engine on the edge-list path, equivalent to the
    dense einsum reference to f32 mix tolerance."""
    topo = barabasi_albert(1024, p=2, seed=0)
    cfg = dataclasses.replace(_cfg(), rounds=2, eval_every=1)
    strat = AggregationStrategy("degree", tau=0.1, seed=0)
    coeffs = np.asarray(coeffs_stack(topo, strat, 2))[None]
    params0, bank, indices, data_idx, tb, ob = _engine_inputs(
        n=1024, n_exp=1, seed=1)
    indices = indices[:, :2]
    support = topo.adjacency + np.eye(1024)

    def run(impl):
        engine = SweepEngine(
            sgd(1e-2), _loss_fn, _eval_fn,
            dataclasses.replace(cfg, mix_impl=impl),
            mix_support=None if impl == "einsum" else support)
        return engine.run(params0, coeffs, bank, indices, data_idx, tb, ob,
                          batch_size=BATCH)
    _assert_results_close(run("edges"), run("einsum"), rtol=1e-4, atol=1e-4)
