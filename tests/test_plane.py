"""PlaneLayout: the static pack/unpack plan behind the fused plane mix
(DESIGN.md §11) — exact round-trips, dtype policy, static metadata."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.plane import PlaneLayout


def _ragged(n, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    return {
        "w": jax.random.normal(ks[0], (n, 4, 6)),
        "b": jax.random.normal(ks[1], (n, 5)),
        "deep": {"u": jax.random.normal(ks[2], (n, 3, 2, 2))},
        "scalar": jax.random.normal(ks[3], (n,)),
    }


class TestLayout:
    def test_offsets_partition_the_plane(self):
        p = _ragged(6)
        lo = PlaneLayout.from_tree(p)
        assert lo.n_nodes == 6
        sizes = [s.size for s in lo.slots]
        offsets = [s.offset for s in lo.slots]
        assert offsets == list(np.cumsum([0] + sizes[:-1]))
        assert lo.n_params == sum(sizes) == 4 * 6 + 5 + 3 * 2 * 2 + 1

    def test_roundtrip_exact(self):
        p = _ragged(5)
        lo = PlaneLayout.from_tree(p)
        plane = lo.pack(p)
        assert plane.shape == (5, lo.n_params)
        out = lo.unpack(plane)
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(out)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mixed_dtype_promotes_to_widest(self):
        p = {"a": jnp.ones((3, 2), jnp.bfloat16),
             "b": jnp.ones((3, 4), jnp.float32)}
        lo = PlaneLayout.from_tree(p)
        assert lo.widest_dtype == jnp.float32
        out = lo.unpack(lo.pack(p))
        assert out["a"].dtype == jnp.bfloat16    # leaf dtype restored
        assert out["b"].dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.ones((3, 2), np.float32))

    def test_all_bf16_tree_packs_bf16(self):
        p = {"a": jnp.ones((3, 2), jnp.bfloat16),
             "b": jnp.ones((3, 4), jnp.bfloat16)}
        assert PlaneLayout.from_tree(p).pack(p).dtype == jnp.bfloat16

    def test_forced_bf16_plane_is_storage_cast_only(self):
        p = _ragged(4)
        lo = PlaneLayout.from_tree(p)
        out = lo.unpack(lo.pack(p, dtype=jnp.bfloat16))
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(out)):
            assert b.dtype == a.dtype  # f32 restored
            np.testing.assert_array_equal(
                np.asarray(b), np.asarray(a.astype(jnp.bfloat16),
                                          np.float32))

    def test_layout_is_static_and_hashable(self):
        p = _ragged(4)
        a, b = PlaneLayout.from_tree(p), PlaneLayout.from_tree(_ragged(4, 1))
        assert a == b and hash(a) == hash(b)
        # built from tracers too (shape/dtype only)
        traced = jax.eval_shape(lambda q: q, p)
        assert PlaneLayout.from_tree(traced) == a

    def test_single_leaf_no_concat(self):
        p = {"w": jnp.arange(12.0).reshape(3, 4)}
        lo = PlaneLayout.from_tree(p)
        np.testing.assert_array_equal(np.asarray(lo.pack(p)),
                                      np.asarray(p["w"]))

    def test_pack_rejects_foreign_tree(self):
        """Reusing a layout on a structurally different tree must error,
        not silently mis-offset columns."""
        lo = PlaneLayout.from_tree({"w": jnp.ones((3, 6))})
        with pytest.raises(ValueError, match="mismatch"):
            lo.pack({"w": jnp.ones((3, 2)), "v": jnp.ones((3, 4))})
        with pytest.raises(ValueError, match="mismatch"):
            lo.pack({"w": jnp.ones((3, 2, 3))})  # same size, wrong shape

    def test_unpack_rejects_wrong_width(self):
        lo = PlaneLayout.from_tree({"w": jnp.ones((3, 6))})
        with pytest.raises(ValueError, match="columns"):
            lo.unpack(jnp.ones((3, 7)))

    def test_rejects_mismatched_node_axis(self):
        with pytest.raises(ValueError, match="node axis"):
            PlaneLayout.from_tree({"a": jnp.ones((3, 2)),
                                   "b": jnp.ones((4, 2))})

    def test_rejects_empty_tree(self):
        with pytest.raises(ValueError, match="empty"):
            PlaneLayout.from_tree({})

    def test_pack_under_vmap(self):
        """vmap over an experiment axis must commute with pack/unpack —
        the sweep engine packs inside vmap_E."""
        p = _ragged(4)
        pE = jax.tree.map(lambda x: jnp.stack([x, 2 * x]), p)
        lo = PlaneLayout.from_tree(p)
        planes = jax.vmap(lo.pack)(pE)
        np.testing.assert_array_equal(np.asarray(planes[0]),
                                      np.asarray(lo.pack(p)))
        out = jax.vmap(lo.unpack)(planes)
        for a, b in zip(jax.tree.leaves(pE), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(n=st.integers(1, 9), seed=st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_property_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    n_leaves = rng.integers(1, 5)
    p = {}
    for i in range(n_leaves):
        shape = (n,) + tuple(rng.integers(1, 7, size=rng.integers(0, 3)))
        p[f"l{i}"] = jnp.asarray(rng.normal(size=shape), jnp.float32)
    lo = PlaneLayout.from_tree(p)
    out = lo.unpack(lo.pack(p))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestRowBridge:
    """pack_row / unpack_row — the serving-side bridge: one node's params
    ↔ one plane row (FleetScheduler.swap_node)."""

    def test_pack_row_matches_full_pack(self):
        p = _ragged(5)
        lo = PlaneLayout.from_tree(p)
        plane = lo.pack(p)
        one = jax.tree.map(lambda x: x[2], p)
        np.testing.assert_array_equal(np.asarray(lo.pack_row(one)),
                                      np.asarray(plane[2]))

    def test_row_roundtrip_exact(self):
        p = _ragged(4)
        lo = PlaneLayout.from_tree(p)
        one = jax.tree.map(lambda x: x[3], p)
        out = lo.unpack_row(lo.pack_row(one))
        for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(out)):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_swap_row_equals_repack(self):
        """plane.at[k].set(pack_row(new)) must equal packing a tree whose
        row k was replaced — the no-re-jit model swap is a pure row
        write."""
        p = _ragged(4, seed=0)
        q = _ragged(4, seed=1)
        lo = PlaneLayout.from_tree(p)
        new_row = jax.tree.map(lambda x: x[1], q)
        swapped = lo.pack(p).at[1].set(lo.pack_row(new_row))
        repacked = lo.pack(jax.tree.map(
            lambda a, b: a.at[1].set(b[1]), p, q))
        np.testing.assert_array_equal(np.asarray(swapped),
                                      np.asarray(repacked))

    def test_pack_row_rejects_foreign_tree(self):
        lo = PlaneLayout.from_tree({"w": jnp.ones((3, 6))})
        with pytest.raises(ValueError, match="pack_row"):
            lo.pack_row({"w": jnp.ones((7,))})
        with pytest.raises(ValueError, match="pack_row"):
            lo.pack_row({"w": jnp.ones((6,)), "v": jnp.ones((2,))})
