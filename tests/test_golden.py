"""Golden-run regression suite: the streaming-analytics numbers of tiny
deterministic ring/star sweeps (single- and multi-source OOD) are pinned
in ``tests/goldens/sweep_analytics.json`` and asserted to tolerance —
per-node IID/OOD accuracy-AUC, arrival rounds, gap, hop fields.

Also locks the tentpole equivalences: the streaming summaries are
bit-identical across the scanned / chunked / mesh-sharded execution
modes (the mesh spans ALL local devices, so the CI golden job's 8
virtual-device run exercises real sharding while a laptop run degrades
to mesh(1)), identical with ``keep_history=False`` (the O(E·n) path),
and match the host-side ``propagation.py`` oracles to 1e-6 (asserted
inside ``compute_goldens``).

Regenerate after an intentional numerical change:
    PYTHONPATH=src python -m tests.regen_goldens
"""
import json
import os

import numpy as np
import pytest

from tests import regen_goldens as rg


@pytest.fixture(scope="module")
def computed():
    return rg.compute_goldens()


def _load_goldens():
    assert os.path.exists(rg.GOLDEN_PATH), (
        f"missing {rg.GOLDEN_PATH}; generate it with "
        f"`PYTHONPATH=src python -m tests.regen_goldens`")
    with open(rg.GOLDEN_PATH) as f:
        return json.load(f)


def test_golden_values_match(computed):
    want = _load_goldens()
    assert want["meta"] == computed["meta"], (
        "golden meta (scale/threshold) drifted — regenerate the goldens "
        "if the change was intentional")
    assert set(want["scenarios"]) == set(computed["scenarios"])
    for name, g in want["scenarios"].items():
        c = computed["scenarios"][name]
        assert c["ood_sources"] == g["ood_sources"], name
        assert c["hops_from_sources"] == g["hops_from_sources"], name
        np.testing.assert_allclose(c["iid_auc"], g["iid_auc"],
                                   atol=rg.TOL, err_msg=name)
        np.testing.assert_allclose(c["ood_auc"], g["ood_auc"],
                                   atol=rg.TOL, err_msg=name)
        assert c["ood_arrival"] == g["ood_arrival"], name
        # gap = 100·(ood−iid)/iid amplifies AUC drift by ~1/iid_mean
        # (~10× here), so its tolerance must be looser than TOL or any
        # drift that legitimately passes the AUC checks fails here
        np.testing.assert_allclose(c["iid_ood_gap_pct"],
                                   g["iid_ood_gap_pct"],
                                   atol=0.5, err_msg=name)
        np.testing.assert_allclose(c["final_ood_acc_mean"],
                                   g["final_ood_acc_mean"],
                                   atol=rg.TOL, err_msg=name)


def test_golden_chunked_mode_identical(computed):
    """chunk_rounds=2 resumes the analytics carry exactly — the digested
    payload (pure floats/ints) must be EQUAL, not merely close."""
    assert rg.compute_goldens(chunk_rounds=2) == computed


def test_golden_mesh_mode_identical(computed):
    """mesh over all local devices (1 on a laptop, 8 in the CI golden
    job): E-padding + shard_map cannot change any scenario's analytics."""
    from repro.launch.mesh import make_sweep_mesh

    assert rg.compute_goldens(mesh=make_sweep_mesh()) == computed
    assert rg.compute_goldens(mesh=make_sweep_mesh(),
                              chunk_rounds=2) == computed


def test_golden_no_history_identical(computed):
    """keep_history=False (O(E·n) metric memory) produces the same
    streaming summaries; only the oracle cross-check (which needs the
    history) is skipped inside compute_goldens."""
    got = rg.compute_goldens(keep_history=False)
    assert got == computed


# ----------------------------------------------------------------------
# edge-list path suite: n=256 BA via mix_impl="edges"
# (goldens/sweep_analytics_edges.json)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def computed_edges():
    return rg.compute_edges_goldens()


def _load_edges_goldens():
    assert os.path.exists(rg.EDGES_GOLDEN_PATH), (
        f"missing {rg.EDGES_GOLDEN_PATH}; generate it with "
        f"`PYTHONPATH=src python -m tests.regen_goldens`")
    with open(rg.EDGES_GOLDEN_PATH) as f:
        return json.load(f)


def test_edges_golden_values_match(computed_edges):
    want = _load_edges_goldens()
    assert want["meta"] == computed_edges["meta"], (
        "edges golden meta (scale/dmax/threshold) drifted — regenerate "
        "the goldens if the change was intentional")
    assert set(want["scenarios"]) == set(computed_edges["scenarios"])
    for name, g in want["scenarios"].items():
        c = computed_edges["scenarios"][name]
        assert c["ood_sources"] == g["ood_sources"], name
        assert c["max_hops_from_sources"] == g["max_hops_from_sources"], name
        for key in ("src_ood_auc", "iid_auc_mean", "ood_auc_mean",
                    "ood_arrival_mean", "final_ood_acc_mean"):
            np.testing.assert_allclose(c[key], g[key], atol=rg.TOL,
                                       err_msg=f"{name}:{key}")
        np.testing.assert_allclose(c["iid_ood_gap_pct"],
                                   g["iid_ood_gap_pct"], atol=0.5,
                                   err_msg=name)


def test_edges_golden_chunked_mode_identical(computed_edges):
    """chunk_rounds=2 over R=3 resumes the scan carry exactly on the
    edge-list path too — digested payload EQUAL, not merely close."""
    assert rg.compute_edges_goldens(chunk_rounds=2) == computed_edges


def test_edges_golden_mesh_mode_identical(computed_edges):
    """E-padding (E=2 onto the local device count) + shard_map around the
    edges kernel cannot change any scenario's analytics."""
    from repro.launch.mesh import make_sweep_mesh

    assert rg.compute_edges_goldens(mesh=make_sweep_mesh()) == computed_edges


def test_edges_golden_no_history_identical(computed_edges):
    assert rg.compute_edges_goldens(keep_history=False) == computed_edges


# ----------------------------------------------------------------------
# partial-participation suite (goldens/sweep_participation.json):
# staleness counters, time-skewed local steps, arrival under node-level
# dropout on ring + BA — DESIGN.md §15.  compute_participation_goldens
# additionally asserts the rate-1.0 scenario bit-identical to the
# synchronous engine on every primary run.
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def computed_participation():
    return rg.compute_participation_goldens()


def _load_participation_goldens():
    assert os.path.exists(rg.PARTICIPATION_GOLDEN_PATH), (
        f"missing {rg.PARTICIPATION_GOLDEN_PATH}; generate it with "
        f"`PYTHONPATH=src python -m tests.regen_goldens`")
    with open(rg.PARTICIPATION_GOLDEN_PATH) as f:
        return json.load(f)


def test_participation_golden_values_match(computed_participation):
    want = _load_participation_goldens()
    assert want["meta"] == computed_participation["meta"], (
        "participation golden meta (scale/spec) drifted — regenerate the "
        "goldens if the change was intentional")
    assert set(want["scenarios"]) == set(computed_participation["scenarios"])
    for name, g in want["scenarios"].items():
        c = computed_participation["scenarios"][name]
        # the active-set draw is a pinned PRNG stream: every counter is
        # an exact integer, not a tolerance value
        for key in ("rate", "ood_sources", "rounds_active",
                    "final_staleness", "local_steps", "ood_arrival"):
            assert c[key] == g[key], (name, key)
        np.testing.assert_allclose(c["mean_staleness"], g["mean_staleness"],
                                   atol=1e-9, err_msg=name)
        np.testing.assert_allclose(c["ood_auc_mean"], g["ood_auc_mean"],
                                   atol=rg.TOL, err_msg=name)
        np.testing.assert_allclose(c["activity_rate"], g["activity_rate"],
                                   atol=1e-9, err_msg=name)
        if g["staleness_arrival_corr"] is None:
            assert c["staleness_arrival_corr"] is None, name
        else:
            np.testing.assert_allclose(c["staleness_arrival_corr"],
                                       g["staleness_arrival_corr"],
                                       atol=1e-6, err_msg=name)


def test_participation_golden_chunked_mode_identical(computed_participation):
    """Absolute round indices drive the active-set draw, so chunk
    boundaries cannot shift it — digested payload EQUAL."""
    assert (rg.compute_participation_goldens(chunk_rounds=2)
            == computed_participation)


def test_participation_golden_mesh_mode_identical(computed_participation):
    """The participation carry shards on E like the analytics carry;
    E-padding + shard_map cannot change any counter."""
    from repro.launch.mesh import make_sweep_mesh

    assert (rg.compute_participation_goldens(mesh=make_sweep_mesh())
            == computed_participation)
    assert (rg.compute_participation_goldens(mesh=make_sweep_mesh(),
                                             chunk_rounds=2)
            == computed_participation)


def test_participation_golden_no_history_identical(computed_participation):
    assert (rg.compute_participation_goldens(keep_history=False)
            == computed_participation)


# ----------------------------------------------------------------------
# byzantine robustness suite (goldens/sweep_byzantine.json): signflip
# faults at a pinned rate grid aggregated by mean / trimmed / median /
# mean+quarantine — DESIGN.md §16.  compute_byzantine_goldens itself
# asserts the rate-0.0 mean cell bit-identical to the fault-free engine
# and that the robust aggregators recover final OOD accuracy >= plain
# mean under every nonzero fault rate (the headline robustness claim).
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def computed_byzantine():
    return rg.compute_byzantine_goldens()


def _load_byzantine_goldens():
    assert os.path.exists(rg.BYZANTINE_GOLDEN_PATH), (
        f"missing {rg.BYZANTINE_GOLDEN_PATH}; generate it with "
        f"`PYTHONPATH=src python -m tests.regen_goldens`")
    with open(rg.BYZANTINE_GOLDEN_PATH) as f:
        return json.load(f)


def test_byzantine_golden_values_match(computed_byzantine):
    want = _load_byzantine_goldens()
    assert want["meta"] == computed_byzantine["meta"], (
        "byzantine golden meta (fault spec/scale) drifted — regenerate "
        "the goldens if the change was intentional")
    assert set(want["scenarios"]) == set(computed_byzantine["scenarios"])
    for name, g in want["scenarios"].items():
        c = computed_byzantine["scenarios"][name]
        # the fault draw is a pinned PRNG stream: counters are exact ints
        for key in ("fault_rate", "ood_sources", "fault_rounds",
                    "first_fault"):
            assert c[key] == g[key], (name, key)
        assert set(c["aggregators"]) == set(g["aggregators"]), name
        for agg, gv in g["aggregators"].items():
            cv = c["aggregators"][agg]
            assert cv["ood_arrival"] == gv["ood_arrival"], (name, agg)
            for key in ("iid_auc_mean", "ood_auc_mean",
                        "final_ood_acc_mean"):
                np.testing.assert_allclose(cv[key], gv[key], atol=rg.TOL,
                                           err_msg=f"{name}:{agg}:{key}")
        for key, gv in g["quarantine"].items():
            cv = c["quarantine"][key]
            if gv is None:
                assert cv is None, (name, key)
            else:
                np.testing.assert_allclose(cv, gv, atol=1e-9,
                                           err_msg=f"{name}:{key}")


def test_byzantine_golden_chunked_mode_identical(computed_byzantine):
    """Absolute round indices drive the fault draw and the quarantine
    carry resumes across chunk boundaries — digested payload EQUAL."""
    assert rg.compute_byzantine_goldens(chunk_rounds=2) == computed_byzantine


def test_byzantine_golden_mesh_mode_identical(computed_byzantine):
    """The fault/quarantine carry shards on E like the analytics carry;
    E-padding + shard_map cannot change any counter or curve."""
    from repro.launch.mesh import make_sweep_mesh

    assert (rg.compute_byzantine_goldens(mesh=make_sweep_mesh())
            == computed_byzantine)


def test_byzantine_golden_no_history_identical(computed_byzantine):
    assert (rg.compute_byzantine_goldens(keep_history=False)
            == computed_byzantine)
