import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.mixing import (
    circulant_decomposition,
    edge_weights,
    mix_dense,
    mix_edges,
    mix_sparse,
    mix_sparse_host,
    mixing_collective_bytes,
    sparse_offsets,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import (
    barabasi_albert,
    padded_neighbor_tables,
    ring,
    stochastic_block,
    watts_strogatz,
)


def _params(n, seed=0):
    k = jax.random.key(seed)
    ks = jax.random.split(k, 3)
    return {
        "w": jax.random.normal(ks[0], (n, 4, 6)),
        "b": jax.random.normal(ks[1], (n, 5)),
        "scalar_per_node": jax.random.normal(ks[2], (n,)),
    }


class TestDense:
    def test_identity(self):
        p = _params(8)
        out = mix_dense(p, jnp.eye(8))
        for k in p:
            np.testing.assert_allclose(out[k], p[k], rtol=1e-6)

    def test_full_average(self):
        p = _params(8)
        out = mix_dense(p, jnp.full((8, 8), 1 / 8))
        for k in p:
            expected = jnp.broadcast_to(p[k].mean(0, keepdims=True), p[k].shape)
            np.testing.assert_allclose(out[k], expected, rtol=1e-5, atol=1e-6)

    def test_preserves_dtype(self):
        p = {"w": jnp.ones((4, 3), jnp.bfloat16)}
        out = mix_dense(p, jnp.eye(4))
        assert out["w"].dtype == jnp.bfloat16

    def test_mean_preserved_doubly_stochastic(self):
        """Doubly-stochastic mixing preserves the parameter mean — the
        conservation law consensus averaging relies on."""
        t = barabasi_albert(8, 2, 0)
        c = mixing_matrix(t, AggregationStrategy("metropolis"))
        p = _params(8)
        out = mix_dense(p, jnp.asarray(c))
        for k in p:
            np.testing.assert_allclose(
                np.asarray(out[k]).mean(0), np.asarray(p[k]).mean(0),
                rtol=1e-4, atol=1e-5)


class TestCirculant:
    @pytest.mark.parametrize("kind", ["unweighted", "degree", "random"])
    def test_matches_dense(self, kind):
        t = barabasi_albert(12, 2, 1)
        c = mixing_matrix(t, AggregationStrategy(kind, tau=0.1, seed=3))
        sched = circulant_decomposition(c)
        p = _params(12)
        d = mix_dense(p, jnp.asarray(c))
        s = mix_sparse_host(p, sched)
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(s[k]),
                                       rtol=1e-4, atol=1e-5)

    def test_ring_has_three_offsets(self):
        t = ring(8)
        c = mixing_matrix(t, AggregationStrategy("unweighted"))
        sched = circulant_decomposition(c)
        assert sorted(sched.offsets) == [0, 1, 7]

    def test_collective_bytes_ring_vs_dense(self):
        t = ring(16)
        c = mixing_matrix(t, AggregationStrategy("unweighted"))
        sched = circulant_decomposition(c)
        b = mixing_collective_bytes(16, 10**9, sched)
        assert b["sparse_bytes_per_node"] == 2 * 10**9
        assert b["dense_bytes_per_node"] == 15 * 10**9


class TestMixImplSparse:
    """make_mix_fn(mix_impl='sparse'): static offsets from the topology
    support, per-call weights gathered from the traced matrix."""

    TOPOS = [
        lambda: barabasi_albert(14, 2, seed=1),
        lambda: watts_strogatz(12, 4, 0.5, seed=2),
        lambda: stochastic_block(13, 3, 0.5, 0.05, seed=3),
        lambda: ring(10),
    ]

    @pytest.mark.parametrize("topo_i", range(4))
    @pytest.mark.parametrize("kind", ["unweighted", "degree", "random"])
    def test_matches_dense_on_topology_matrices(self, topo_i, kind):
        from repro.core.decentralized import make_mix_fn

        topo = self.TOPOS[topo_i]()
        support = topo.adjacency + np.eye(topo.n_nodes)
        c = mixing_matrix(topo, AggregationStrategy(kind, tau=0.1, seed=5))
        # slack high enough that no BA/WS/SB case falls back to dense —
        # this exercises the actual roll-and-accumulate schedule
        mix = make_mix_fn("sparse", mix_support=support,
                          sparse_slack=topo.n_nodes)
        p = _params(topo.n_nodes)
        d = mix_dense(p, jnp.asarray(c))
        s = mix(p, jnp.asarray(c))
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(s[k]),
                                       rtol=1e-4, atol=1e-5)

    def test_sparse_offsets_cover_support(self):
        topo = barabasi_albert(12, 2, seed=0)
        support = topo.adjacency + np.eye(12)
        offsets = sparse_offsets(support)
        rows = np.arange(12)
        covered = np.zeros_like(support)
        for k in offsets:
            covered[rows, (rows + k) % 12] = 1.0
        assert np.all(covered >= support)

    def test_mix_sparse_direct_ring(self):
        topo = ring(8)
        c = mixing_matrix(topo, AggregationStrategy("unweighted"))
        offsets = sparse_offsets(topo.adjacency + np.eye(8))
        assert sorted(offsets) == [0, 1, 7]
        p = _params(8)
        d = mix_dense(p, jnp.asarray(c))
        s = mix_sparse(p, jnp.asarray(c), offsets)
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(s[k]),
                                       rtol=1e-5, atol=1e-6)

    def test_dense_fallback_when_offsets_exceed_max_degree(self):
        """A bounded-degree graph whose edges hit many distinct ring
        offsets: the decomposition would permute more than max degree +
        slack times, so make_mix_fn returns mix_dense itself."""
        from repro.core.decentralized import make_mix_fn

        n = 16
        a = np.zeros((n, n))
        for i, j in [(0, 5), (1, 9), (2, 12), (3, 7), (4, 14), (6, 13),
                     (8, 15), (10, 11)]:   # perfect matching, max degree 1
            a[i, j] = a[j, i] = 1.0
        support = a + np.eye(n)
        assert len(sparse_offsets(support)) > 1 + 4  # many offsets
        mix = make_mix_fn("sparse", mix_support=support, sparse_slack=4)
        assert mix is mix_dense

    def test_sparse_requires_support(self):
        from repro.core.decentralized import make_mix_fn

        with pytest.raises(ValueError, match="mix_support"):
            make_mix_fn("sparse")

    def test_trainer_sparse_fl_uses_full_support(self):
        """FL's dense 1/n matrix has weight outside the topology
        neighbourhoods — the trainer must hand mix_impl='sparse' FULL
        support (every ring offset present) so no mass is silently
        dropped; the run matches einsum to accumulation-order
        tolerance."""
        import dataclasses as dc

        from tests.test_sweep import CFG, _run_mlp

        cfg = dc.replace(CFG, rounds=2, eval_every=1)
        p_e, _ = _run_mlp(AggregationStrategy("fl"), cfg)
        p_s, _ = _run_mlp(AggregationStrategy("fl"),
                          dc.replace(cfg, mix_impl="sparse"))
        for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_engine_rejects_off_support_coefficients(self):
        """SweepEngine(mix_impl='sparse') must refuse grids whose
        coefficients exceed the mix_support schedule instead of silently
        mixing sub-stochastically — both for slabs and for programs with
        an fl cell."""
        from repro.core.coeffs import ProgramCoeffs, program_for, stack_states
        from repro.core.decentralized import DecentralizedConfig
        from repro.core.sweep import SweepEngine
        from repro.training.optimizer import sgd
        from tests.test_sweep import _eval_fn, _loss_fn, _mlp_init

        topo = ring(4)
        cfg = DecentralizedConfig(rounds=2, local_epochs=1, eval_every=1,
                                  mix_impl="sparse", epoch_shuffle=False)
        engine = SweepEngine(sgd(1e-2), _loss_fn, _eval_fn, cfg,
                             mix_support=topo.adjacency + np.eye(4))
        p0 = jax.tree.map(lambda x: jnp.asarray(x)[None], _mlp_init(0))
        params0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (1, 4) + x.shape[1:]), p0)
        bank = {"x": np.zeros((1, 4, 8, 5), np.float32),
                "y": np.zeros((1, 4, 8, 2), np.float32)}
        indices = np.zeros((1, 2, 4, 4), np.int32)
        data_idx = np.zeros(1, np.int32)
        tb = {"x": np.zeros((1, 8, 5), np.float32),
              "y": np.zeros((1, 8, 2), np.float32)}
        run = lambda c: engine.run(params0, c, bank, indices, data_idx,
                                   tb, tb, batch_size=4)
        fl_slab = np.full((1, 2, 4, 4), 0.25, np.float32)
        with pytest.raises(ValueError, match="mix_support"):
            run(fl_slab)
        _, state = program_for(topo, AggregationStrategy("fl"))
        with pytest.raises(ValueError, match="mix_support"):
            run(ProgramCoeffs(program_for(topo, AggregationStrategy("fl"))[0],
                              stack_states([state])))
        # in-support coefficients pass the guard and run
        ok = engine.run(
            params0,
            np.broadcast_to(
                mixing_matrix(topo, AggregationStrategy("unweighted"))
                .astype(np.float32), (1, 2, 4, 4)).copy(),
            bank, indices, data_idx, tb, tb, batch_size=4)
        assert ok.train_loss.shape == (1, 2, 4)

    def test_fl_support_drops_no_mass(self):
        """Regression: with neighbour-only support, mix_sparse on FL's
        matrix would return sub-stochastic rows; full support keeps the
        exact full average."""
        topo = ring(6)
        c = mixing_matrix(topo, AggregationStrategy("fl"))
        full = sparse_offsets(np.ones((6, 6)))
        p = _params(6)
        out = mix_sparse(p, jnp.asarray(c), full)
        for k in p:
            expected = np.broadcast_to(
                np.asarray(p[k]).mean(0, keepdims=True), p[k].shape)
            np.testing.assert_allclose(np.asarray(out[k]), expected,
                                       rtol=1e-5, atol=1e-6)
        # neighbour-only support on FL would drop mass — guard the guard
        nbr = sparse_offsets(topo.adjacency + np.eye(6))
        bad = mix_sparse({"x": jnp.ones((6, 2))}, jnp.asarray(c), nbr)
        assert np.all(np.asarray(bad["x"]) < 0.99)

    def test_trainer_sparse_impl_matches_einsum(self):
        """DecentralizedConfig(mix_impl='sparse') wires the topology
        support through make_round_fn — same run as einsum to f32
        tolerance."""
        import dataclasses as dc

        from tests.test_sweep import CFG, _run_mlp

        strat = AggregationStrategy("degree", tau=0.1)
        cfg = dc.replace(CFG, rounds=2, eval_every=1)
        p_e, h_e = _run_mlp(strat, cfg)
        p_s, h_s = _run_mlp(strat, dc.replace(cfg, mix_impl="sparse"))
        for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        for ma, mb in zip(h_e, h_s):
            np.testing.assert_allclose(ma.train_loss, mb.train_loss,
                                       rtol=1e-5, atol=1e-6)


class TestMixImplEdges:
    """make_mix_fn(mix_impl='edges'): static padded-ELL neighbour tables
    from the topology support, per-round weights gathered from the traced
    matrix, executed as ONE Pallas segment kernel over the flat plane."""

    TOPOS = [
        lambda: barabasi_albert(14, 2, seed=1),
        lambda: watts_strogatz(12, 4, 0.5, seed=2),
        lambda: stochastic_block(13, 3, 0.5, 0.05, seed=3),
        lambda: ring(10),
    ]

    @pytest.mark.parametrize("topo_i", range(4))
    @pytest.mark.parametrize("kind", ["unweighted", "degree", "random"])
    def test_matches_dense_on_topology_matrices(self, topo_i, kind):
        from repro.core.decentralized import make_mix_fn

        topo = self.TOPOS[topo_i]()
        support = topo.adjacency + np.eye(topo.n_nodes)
        c = mixing_matrix(topo, AggregationStrategy(kind, tau=0.1, seed=5))
        mix = make_mix_fn("edges", mix_support=support)
        p = _params(topo.n_nodes)
        d = mix_dense(p, jnp.asarray(c))
        e = mix(p, jnp.asarray(c))
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(e[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_mix_edges_reference_isolated_and_self_loop_rows(self):
        """Degenerate rows behave exactly like the dense contraction: a
        self-loop-only row keeps its own params, an all-zero coefficient
        row (isolated node) comes back zero, with or without a self slot
        in the tables."""
        n = 8
        support = np.zeros((n, n))
        support[0, 1] = support[1, 0] = 1.0  # only nodes 0/1 have an edge
        c = np.zeros((n, n))
        c[0, 1] = 1.0
        c[1, 0] = 0.5
        c[1, 1] = 0.5
        c[2, 2] = 1.0                        # self-loop-only row
        # rows 3.. are all-zero (isolated, no self weight either)
        p = _params(n)
        d = mix_dense(p, jnp.asarray(c))
        for with_diag in (True, False):
            s = support + np.eye(n) if with_diag else support.copy()
            s[1, 1] = 1.0                    # row 1 carries self weight
            s[2, 2] = 1.0                    # row 2's self-loop support
            idx, msk = padded_neighbor_tables(s)
            e = mix_edges(p, jnp.asarray(c), jnp.asarray(idx),
                          jnp.asarray(msk))
            for k in p:
                np.testing.assert_allclose(np.asarray(d[k]),
                                           np.asarray(e[k]),
                                           rtol=1e-6, atol=1e-6)

    def test_edge_weights_gather(self):
        topo = ring(6)
        idx, msk = padded_neighbor_tables(topo.adjacency + np.eye(6))
        c = mixing_matrix(topo, AggregationStrategy("unweighted"))
        w = np.asarray(edge_weights(jnp.asarray(c), jnp.asarray(idx),
                                    jnp.asarray(msk)))
        rows = np.arange(6)[:, None]
        np.testing.assert_allclose(w, c[rows, idx] * msk, atol=1e-7)
        # every row's gathered weights recover the full row mass
        np.testing.assert_allclose(w.sum(1), np.ones(6), atol=1e-6)

    def test_edges_requires_support(self):
        from repro.core.decentralized import make_mix_fn

        with pytest.raises(ValueError, match="mix_support"):
            make_mix_fn("edges")

    def test_unknown_impl_lists_edges(self):
        from repro.core.decentralized import make_mix_fn

        with pytest.raises(KeyError, match="edges"):
            make_mix_fn("segment")

    def test_link_failure_shrunk_support_reuses_tables(self):
        """Tables from the NOMINAL topology serve matrices whose support
        shrank under link failure — dropped edges just gather weight 0."""
        from repro.core.decentralized import make_mix_fn

        topo = barabasi_albert(12, 2, seed=4)
        support = topo.adjacency + np.eye(12)
        c = np.asarray(mixing_matrix(
            topo, AggregationStrategy("unweighted")))
        rng = np.random.default_rng(0)
        keep = rng.random((12, 12)) < 0.5
        keep = np.triu(keep, 1)
        keep = keep + keep.T + np.eye(12, dtype=bool)
        c2 = c * keep
        mix = make_mix_fn("edges", mix_support=support)
        p = _params(12)
        d = mix_dense(p, jnp.asarray(c2))
        e = mix(p, jnp.asarray(c2))
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(e[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_trainer_edges_impl_matches_einsum(self):
        """DecentralizedConfig(mix_impl='edges') wires the topology
        support through make_round_fn — same run as einsum to f32
        tolerance."""
        import dataclasses as dc

        from tests.test_sweep import CFG, _run_mlp

        strat = AggregationStrategy("degree", tau=0.1)
        cfg = dc.replace(CFG, rounds=2, eval_every=1)
        p_e, h_e = _run_mlp(strat, cfg)
        p_s, h_s = _run_mlp(strat, dc.replace(cfg, mix_impl="edges"))
        for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
        for ma, mb in zip(h_e, h_s):
            np.testing.assert_allclose(ma.train_loss, mb.train_loss,
                                       rtol=1e-5, atol=1e-6)

    def test_trainer_edges_fl_uses_full_support(self):
        """FL's dense 1/n matrix has weight outside the topology
        neighbourhoods — the trainer must hand mix_impl='edges' FULL
        support so no mass is silently dropped."""
        import dataclasses as dc

        from tests.test_sweep import CFG, _run_mlp

        cfg = dc.replace(CFG, rounds=2, eval_every=1)
        p_e, _ = _run_mlp(AggregationStrategy("fl"), cfg)
        p_s, _ = _run_mlp(AggregationStrategy("fl"),
                          dc.replace(cfg, mix_impl="edges"))
        for a, b in zip(jax.tree.leaves(p_e), jax.tree.leaves(p_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_engine_rejects_off_support_coefficients(self):
        """SweepEngine(mix_impl='edges') must refuse grids whose
        coefficients exceed the neighbour tables instead of silently
        mixing sub-stochastically."""
        from repro.core.coeffs import ProgramCoeffs, program_for, stack_states
        from repro.core.decentralized import DecentralizedConfig
        from repro.core.sweep import SweepEngine
        from repro.training.optimizer import sgd
        from tests.test_sweep import _eval_fn, _loss_fn, _mlp_init

        topo = ring(4)
        cfg = DecentralizedConfig(rounds=2, local_epochs=1, eval_every=1,
                                  mix_impl="edges", epoch_shuffle=False)
        engine = SweepEngine(sgd(1e-2), _loss_fn, _eval_fn, cfg,
                             mix_support=topo.adjacency + np.eye(4))
        p0 = jax.tree.map(lambda x: jnp.asarray(x)[None], _mlp_init(0))
        params0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (1, 4) + x.shape[1:]), p0)
        bank = {"x": np.zeros((1, 4, 8, 5), np.float32),
                "y": np.zeros((1, 4, 8, 2), np.float32)}
        indices = np.zeros((1, 2, 4, 4), np.int32)
        data_idx = np.zeros(1, np.int32)
        tb = {"x": np.zeros((1, 8, 5), np.float32),
              "y": np.zeros((1, 8, 2), np.float32)}
        run = lambda c: engine.run(params0, c, bank, indices, data_idx,
                                   tb, tb, batch_size=4)
        fl_slab = np.full((1, 2, 4, 4), 0.25, np.float32)
        with pytest.raises(ValueError, match="mix_support"):
            run(fl_slab)
        _, state = program_for(topo, AggregationStrategy("fl"))
        with pytest.raises(ValueError, match="mix_support"):
            run(ProgramCoeffs(program_for(topo, AggregationStrategy("fl"))[0],
                              stack_states([state])))
        # in-support coefficients pass the guard and run
        ok = engine.run(
            params0,
            np.broadcast_to(
                mixing_matrix(topo, AggregationStrategy("unweighted"))
                .astype(np.float32), (1, 2, 4, 4)).copy(),
            bank, indices, data_idx, tb, tb, batch_size=4)
        assert ok.train_loss.shape == (1, 2, 4)


class TestPlaneMix:
    """mix_impl='pallas' → the fused flat-plane kernel
    (kernels.gossip_mix.mix_plane_pallas): one pallas_call per mix,
    equivalent to mix_dense on ragged multi-leaf pytrees."""

    @pytest.mark.parametrize("n", [4, 7, 12])
    @pytest.mark.parametrize("kind", ["unweighted", "degree", "random"])
    def test_matches_dense_on_topology_matrices(self, n, kind):
        topo = barabasi_albert(n, 2, seed=1)
        c = jnp.asarray(mixing_matrix(topo, AggregationStrategy(
            kind, tau=0.1, seed=5)))
        from repro.core.decentralized import make_mix_fn

        mix = make_mix_fn("pallas")
        p = _params(n)
        d = mix_dense(p, c)
        f = mix(p, c)
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(f[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_preserves_leaf_dtypes(self):
        from repro.kernels.gossip_mix import mix_plane_pallas

        p = {"w": jnp.ones((4, 3), jnp.bfloat16),
             "v": jnp.ones((4, 5), jnp.float32)}
        out = mix_plane_pallas(p, jnp.eye(4))
        assert out["w"].dtype == jnp.bfloat16
        assert out["v"].dtype == jnp.float32

    def test_bf16_plane_storage(self):
        """plane_dtype=bf16 halves kernel HBM traffic; f32 accumulation
        is preserved so the result only degrades by the storage cast."""
        from repro.kernels.gossip_mix import mix_plane_pallas

        n = 8
        p = _params(n)
        c = jnp.asarray(mixing_matrix(barabasi_albert(n, 2, 0),
                                      AggregationStrategy("degree", tau=0.1)))
        d = mix_dense(p, c)
        f = mix_plane_pallas(p, c, plane_dtype=jnp.bfloat16)
        for k in p:
            assert f[k].dtype == p[k].dtype
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(f[k]),
                                       rtol=2e-2, atol=2e-2)

    def test_row_stochastic_invariance(self):
        """Constant-across-nodes params are a fixed point of every
        row-stochastic matrix under the fused path."""
        from repro.kernels.gossip_mix import mix_plane_pallas

        n = 6
        base = _params(1, seed=3)
        p = jax.tree.map(
            lambda x: jnp.broadcast_to(x[:1], (n,) + x.shape[1:]), base)
        c = jax.nn.softmax(
            jax.random.normal(jax.random.key(0), (n, n)), axis=1)
        out = mix_plane_pallas(p, c)
        for k in p:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(p[k]),
                                       rtol=1e-6, atol=1e-6)


class TestMixInFloat32:
    """DecentralizedConfig.mix_in_float32 is a real knob: every backend
    accumulates in f32 when True (default) and in the native param/plane
    dtype when False."""

    def _bf16_params(self, n=8):
        p = _params(n, seed=9)
        return jax.tree.map(lambda x: (x * 2).astype(jnp.bfloat16), p)

    def _coeffs(self, n=8):
        t = barabasi_albert(n, 2, 0)
        return jnp.asarray(mixing_matrix(
            t, AggregationStrategy("degree", tau=0.1)), jnp.float32), t

    @pytest.mark.parametrize("impl", ["einsum", "pallas", "sparse", "edges"])
    def test_flag_changes_bf16_accumulation(self, impl):
        from repro.core.decentralized import make_mix_fn

        n = 8
        c, topo = self._coeffs(n)
        p = self._bf16_params(n)
        support = topo.adjacency + np.eye(n)
        if impl == "sparse":
            kw = dict(mix_support=support, sparse_slack=n)
        elif impl == "edges":
            kw = dict(mix_support=support)
        else:
            kw = {}
        hi = make_mix_fn(impl, mix_in_float32=True, **kw)(p, c)
        lo = make_mix_fn(impl, mix_in_float32=False, **kw)(p, c)
        diff = any(
            np.any(np.asarray(a, np.float32) != np.asarray(b, np.float32))
            for a, b in zip(jax.tree.leaves(hi), jax.tree.leaves(lo)))
        assert diff, f"{impl}: accumulation dtype had no effect"
        # low-precision einsum path == explicit bf16 oracle
        if impl == "einsum":
            for k in p:
                oracle = jnp.tensordot(
                    c.astype(jnp.bfloat16), p[k], axes=(1, 0))
                np.testing.assert_array_equal(
                    np.asarray(lo[k], np.float32),
                    np.asarray(oracle, np.float32))

    def test_f32_leaves_unaffected(self):
        """On f32 params the flag is a no-op — the seeded goldens stay
        valid whichever way it is set."""
        from repro.core.decentralized import make_mix_fn

        c, _ = self._coeffs(8)
        p = _params(8)
        hi = make_mix_fn("einsum", mix_in_float32=True)(p, c)
        lo = make_mix_fn("einsum", mix_in_float32=False)(p, c)
        for a, b in zip(jax.tree.leaves(hi), jax.tree.leaves(lo)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestConfigThreading:
    """DecentralizedConfig.{mix_in_float32,sparse_slack} must actually
    reach make_mix_fn from both engines (they were dead/unreachable
    before the fused-plane refactor)."""

    def _spy(self, monkeypatch):
        import repro.core.decentralized as dec

        seen = {}
        real = dec.make_mix_fn

        def spy(mix_impl="einsum", mix_support=None, sparse_slack=4,
                mix_in_float32=True, robust="mean", robust_trim=1,
                robust_clip=1.0):
            seen.update(sparse_slack=sparse_slack,
                        mix_in_float32=mix_in_float32,
                        robust=robust, robust_trim=robust_trim)
            return real(mix_impl, mix_support=mix_support,
                        sparse_slack=sparse_slack,
                        mix_in_float32=mix_in_float32,
                        robust=robust, robust_trim=robust_trim,
                        robust_clip=robust_clip)

        monkeypatch.setattr(dec, "make_mix_fn", spy)
        return seen

    def test_trainer_threads_knobs(self, monkeypatch):
        from repro.core.decentralized import (
            DecentralizedConfig, DecentralizedTrainer)
        from repro.training.optimizer import sgd

        seen = self._spy(monkeypatch)
        cfg = DecentralizedConfig(mix_in_float32=False, sparse_slack=9,
                                  robust="trimmed", robust_trim=2)
        DecentralizedTrainer(ring(4), AggregationStrategy("unweighted"),
                             sgd(1e-2), lambda p, b: 0.0,
                             lambda p, t: 0.0, cfg)
        assert seen == {"sparse_slack": 9, "mix_in_float32": False,
                        "robust": "trimmed", "robust_trim": 2}

    def test_engine_threads_knobs(self, monkeypatch):
        from repro.core.decentralized import DecentralizedConfig
        from repro.core.sweep import SweepEngine
        from repro.training.optimizer import sgd

        seen = self._spy(monkeypatch)
        cfg = DecentralizedConfig(mix_in_float32=False, sparse_slack=7,
                                  robust="median")
        SweepEngine(sgd(1e-2), lambda p, b: 0.0, lambda p, t: 0.0, cfg,
                    mix_support=np.ones((4, 4)))
        assert seen == {"sparse_slack": 7, "mix_in_float32": False,
                        "robust": "median", "robust_trim": 1}

    def test_sparse_slack_changes_fallback_decision(self):
        """The threaded slack is live: the perfect-matching support falls
        back to dense at the default slack but keeps the ring schedule
        when the config-routed slack covers its offset count."""
        from repro.core.decentralized import make_round_fn
        from repro.training.optimizer import sgd

        n = 16
        a = np.zeros((n, n))
        for i, j in [(0, 5), (1, 9), (2, 12), (3, 7), (4, 14), (6, 13),
                     (8, 15), (10, 11)]:
            a[i, j] = a[j, i] = 1.0
        support = a + np.eye(n)
        from repro.core.decentralized import make_mix_fn

        assert make_mix_fn("sparse", mix_support=support,
                           sparse_slack=4) is mix_dense
        assert make_mix_fn("sparse", mix_support=support,
                           sparse_slack=n) is not mix_dense
        c = jnp.asarray(support / support.sum(1, keepdims=True), jnp.float32)
        p = _params(n)
        opt = sgd(1e-2)
        loss = lambda q, b: sum(jnp.sum(l) for l in jax.tree.leaves(q)) * 0.0
        outs = []
        for slack in (4, n):
            rf = make_round_fn(loss, opt, local_epochs=1, mix_impl="sparse",
                               epoch_shuffle=False, mix_support=support,
                               sparse_slack=slack)
            o = jax.vmap(opt.init)(p)
            batches = {"x": jnp.zeros((n, 1, 2, 1))}
            mixed, _, _ = rf(p, o, batches, c)
            outs.append(mixed)
        # both slacks agree with dense on an in-support matrix
        d = mix_dense(p, c)
        for out in outs:
            for k in p:
                np.testing.assert_allclose(np.asarray(d[k]),
                                           np.asarray(out[k]),
                                           rtol=1e-4, atol=1e-5)


@given(n=st.integers(4, 16), seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_property_circulant_exact(n, seed):
    rng = np.random.default_rng(seed)
    c = rng.random((n, n)) * (rng.random((n, n)) < 0.4)
    c += np.eye(n)
    c /= c.sum(1, keepdims=True)
    sched = circulant_decomposition(c)
    x = rng.normal(size=(n, 7)).astype(np.float32)
    d = np.asarray(mix_dense({"x": jnp.asarray(x)}, jnp.asarray(c))["x"])
    s = np.asarray(mix_sparse_host({"x": jnp.asarray(x)}, sched)["x"])
    np.testing.assert_allclose(d, s, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(d, c.astype(np.float32) @ x, rtol=1e-4, atol=1e-4)


@given(n=st.integers(8, 16), seed=st.integers(0, 10),
       family=st.sampled_from(["ba", "ws", "sb"]))
@settings(max_examples=15, deadline=None)
def test_property_edges_matches_dense(n, seed, family):
    """mix_impl='edges' == dense einsum to 1e-6 on random BA/WS/SB graphs
    with random row-stochastic coefficients, including a forced
    isolated-node row (zero coefficient mass -> zero output) and a forced
    self-loop-only row (identity pass-through)."""
    if family == "ba":
        topo = barabasi_albert(n, p=2, seed=seed)
    elif family == "ws":
        topo = watts_strogatz(n, k=4, u=0.3, seed=seed)
    else:
        topo = stochastic_block(n, n_communities=2, seed=seed)
    rng = np.random.default_rng(seed)
    support = np.asarray(topo.adjacency, dtype=np.float64) + np.eye(n)
    iso, selfy = 0, 1
    support[iso, :] = 0.0                    # isolated node: no in-edges
    support[selfy, :] = 0.0
    support[selfy, selfy] = 1.0              # self-loop-only node
    c = rng.random((n, n)) * (support > 0)
    row = c.sum(1, keepdims=True)
    c = np.where(row > 0, c / np.where(row > 0, row, 1.0), 0.0)
    c = c.astype(np.float32)

    x = rng.normal(size=(n, 9)).astype(np.float32)
    dense = np.asarray(mix_dense({"x": jnp.asarray(x)}, jnp.asarray(c))["x"])
    assert np.all(dense[iso] == 0.0)
    np.testing.assert_allclose(dense[selfy], x[selfy], rtol=1e-6, atol=1e-6)

    nbr_idx, nbr_mask = padded_neighbor_tables(support)
    ref = np.asarray(
        mix_edges({"x": jnp.asarray(x)}, jnp.asarray(c), nbr_idx, nbr_mask)["x"])
    np.testing.assert_allclose(ref, dense, rtol=1e-6, atol=1e-6)

    from repro.core.decentralized import make_mix_fn
    mix = make_mix_fn(mix_impl="edges", mix_support=support)
    out = np.asarray(mix({"x": jnp.asarray(x)}, jnp.asarray(c))["x"])
    np.testing.assert_allclose(out, dense, rtol=1e-6, atol=1e-6)
