import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.mixing import (
    circulant_decomposition,
    mix_dense,
    mix_sparse_host,
    mixing_collective_bytes,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import barabasi_albert, ring


def _params(n, seed=0):
    k = jax.random.key(seed)
    ks = jax.random.split(k, 3)
    return {
        "w": jax.random.normal(ks[0], (n, 4, 6)),
        "b": jax.random.normal(ks[1], (n, 5)),
        "scalar_per_node": jax.random.normal(ks[2], (n,)),
    }


class TestDense:
    def test_identity(self):
        p = _params(8)
        out = mix_dense(p, jnp.eye(8))
        for k in p:
            np.testing.assert_allclose(out[k], p[k], rtol=1e-6)

    def test_full_average(self):
        p = _params(8)
        out = mix_dense(p, jnp.full((8, 8), 1 / 8))
        for k in p:
            expected = jnp.broadcast_to(p[k].mean(0, keepdims=True), p[k].shape)
            np.testing.assert_allclose(out[k], expected, rtol=1e-5, atol=1e-6)

    def test_preserves_dtype(self):
        p = {"w": jnp.ones((4, 3), jnp.bfloat16)}
        out = mix_dense(p, jnp.eye(4))
        assert out["w"].dtype == jnp.bfloat16

    def test_mean_preserved_doubly_stochastic(self):
        """Doubly-stochastic mixing preserves the parameter mean — the
        conservation law consensus averaging relies on."""
        t = barabasi_albert(8, 2, 0)
        c = mixing_matrix(t, AggregationStrategy("metropolis"))
        p = _params(8)
        out = mix_dense(p, jnp.asarray(c))
        for k in p:
            np.testing.assert_allclose(
                np.asarray(out[k]).mean(0), np.asarray(p[k]).mean(0),
                rtol=1e-4, atol=1e-5)


class TestCirculant:
    @pytest.mark.parametrize("kind", ["unweighted", "degree", "random"])
    def test_matches_dense(self, kind):
        t = barabasi_albert(12, 2, 1)
        c = mixing_matrix(t, AggregationStrategy(kind, tau=0.1, seed=3))
        sched = circulant_decomposition(c)
        p = _params(12)
        d = mix_dense(p, jnp.asarray(c))
        s = mix_sparse_host(p, sched)
        for k in p:
            np.testing.assert_allclose(np.asarray(d[k]), np.asarray(s[k]),
                                       rtol=1e-4, atol=1e-5)

    def test_ring_has_three_offsets(self):
        t = ring(8)
        c = mixing_matrix(t, AggregationStrategy("unweighted"))
        sched = circulant_decomposition(c)
        assert sorted(sched.offsets) == [0, 1, 7]

    def test_collective_bytes_ring_vs_dense(self):
        t = ring(16)
        c = mixing_matrix(t, AggregationStrategy("unweighted"))
        sched = circulant_decomposition(c)
        b = mixing_collective_bytes(16, 10**9, sched)
        assert b["sparse_bytes_per_node"] == 2 * 10**9
        assert b["dense_bytes_per_node"] == 15 * 10**9


@given(n=st.integers(4, 16), seed=st.integers(0, 10))
@settings(max_examples=15, deadline=None)
def test_property_circulant_exact(n, seed):
    rng = np.random.default_rng(seed)
    c = rng.random((n, n)) * (rng.random((n, n)) < 0.4)
    c += np.eye(n)
    c /= c.sum(1, keepdims=True)
    sched = circulant_decomposition(c)
    x = rng.normal(size=(n, 7)).astype(np.float32)
    d = np.asarray(mix_dense({"x": jnp.asarray(x)}, jnp.asarray(c))["x"])
    s = np.asarray(mix_sparse_host({"x": jnp.asarray(x)}, sched)["x"])
    np.testing.assert_allclose(d, s, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(d, c.astype(np.float32) @ x, rtol=1e-4, atol=1e-4)
