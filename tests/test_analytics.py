"""Streaming-analytics invariants (DESIGN.md §10).

Property tests (hypothesis, optional via tests/_hypothesis.py) plus
deterministic twins that always run:

* a constant accuracy curve has AUC equal to the constant;
* AUC is monotone under pointwise accuracy dominance;
* the in-scan accumulator equals the host ``propagation.py`` oracle for
  random ``eval_every`` schedules and random histories (to 1e-6; arrival
  rounds exactly);
* the accumulator ignores non-eval rounds entirely (garbage accuracies
  at masked-out rounds cannot leak in, mirroring the gated in-scan eval).
"""
import numpy as np
import pytest

from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.analytics import NO_ARRIVAL, AnalyticsSpec, analytics_summary
from repro.core.decentralized import RoundMetrics, eval_round_indices
from repro.core.propagation import arrival_rounds, iid_ood_gap, per_node_auc


def _stream(iid, ood, eval_mask, threshold=0.5):
    """Fold an (R, n) pair of accuracy matrices through the accumulator
    exactly as the scan body does (masked rounds feed zeros, like the
    gated eval)."""
    iid, ood = np.asarray(iid, np.float32), np.asarray(ood, np.float32)
    spec = AnalyticsSpec(arrival_threshold=threshold)
    carry = spec.init(iid.shape[1])
    for r in range(iid.shape[0]):
        m = bool(eval_mask[r])
        carry = spec.update(carry, r, m,
                            iid[r] if m else np.zeros_like(iid[r]),
                            ood[r] if m else np.zeros_like(ood[r]))
    import jax

    return jax.tree.map(np.asarray, spec.finalize(carry))


def _history(iid, ood, eval_mask):
    """The host-side view: RoundMetrics at the eval rounds only."""
    n = iid.shape[1]
    return [RoundMetrics(round=r, iid_acc=np.asarray(iid[r], np.float32),
                         ood_acc=np.asarray(ood[r], np.float32),
                         train_loss=np.zeros(n))
            for r in range(iid.shape[0]) if eval_mask[r]]


def _rand(rng, rounds, n):
    return rng.uniform(0.0, 1.0, size=(rounds, n)).astype(np.float32)


# ----------------------------------------------------------------------
# deterministic invariants (always run)
# ----------------------------------------------------------------------
def test_constant_curve_auc_is_the_constant():
    for c in (0.0, 0.25, 1.0):
        acc = np.full((5, 3), c, np.float32)
        out = _stream(acc, acc, np.ones(5, bool))
        np.testing.assert_allclose(out["iid_auc"], c, atol=1e-6)
        np.testing.assert_allclose(out["ood_auc"], c, atol=1e-6)


def test_auc_monotone_under_dominance():
    rng = np.random.default_rng(0)
    lo = _rand(rng, 8, 4)
    hi = np.clip(lo + rng.uniform(0, 0.5, size=lo.shape), 0, 1)
    mask = np.ones(8, bool)
    assert (_stream(hi, hi, mask)["ood_auc"]
            >= _stream(lo, lo, mask)["ood_auc"] - 1e-6).all()


@pytest.mark.parametrize("eval_every", [1, 2, 3, 5])
def test_stream_matches_host_oracle(eval_every):
    rng = np.random.default_rng(eval_every)
    rounds, n = 9, 5
    iid, ood = _rand(rng, rounds, n), _rand(rng, rounds, n)
    mask = np.zeros(rounds, bool)
    mask[eval_round_indices(rounds, eval_every)] = True
    out = _stream(iid, ood, mask)
    hist = _history(iid, ood, mask)
    np.testing.assert_allclose(out["iid_auc"], per_node_auc(hist, "iid"),
                               atol=1e-6)
    np.testing.assert_allclose(out["ood_auc"], per_node_auc(hist, "ood"),
                               atol=1e-6)
    np.testing.assert_array_equal(out["ood_arrival"],
                                  arrival_rounds(hist, 0.5))
    np.testing.assert_array_equal(
        out["iid_arrival"], arrival_rounds(hist, 0.5, which="iid"))
    np.testing.assert_allclose(
        100.0 * (out["ood_auc"].mean() - out["iid_auc"].mean())
        / max(out["iid_auc"].mean(), 1e-9),
        iid_ood_gap(hist), atol=1e-4)


def test_single_eval_round_degenerates_to_final_accuracy():
    rng = np.random.default_rng(7)
    iid, ood = _rand(rng, 4, 3), _rand(rng, 4, 3)
    mask = np.array([False, False, False, True])
    out = _stream(iid, ood, mask)
    np.testing.assert_allclose(out["iid_auc"], iid[3], atol=1e-7)
    np.testing.assert_allclose(out["ood_auc"], ood[3], atol=1e-7)


def test_masked_rounds_cannot_leak():
    """Garbage at non-eval rounds must not move any accumulator."""
    rng = np.random.default_rng(3)
    iid, ood = _rand(rng, 6, 4), _rand(rng, 6, 4)
    mask = np.array([False, True, False, True, False, True])
    clean = _stream(iid, ood, mask)
    poisoned_iid, poisoned_ood = iid.copy(), ood.copy()
    poisoned_iid[~mask] = 999.0
    poisoned_ood[~mask] = 999.0
    spec = AnalyticsSpec()
    carry = spec.init(4)
    for r in range(6):  # feed the garbage THROUGH update, mask gating it
        carry = spec.update(carry, r, bool(mask[r]),
                            poisoned_iid[r], poisoned_ood[r])
    import jax

    poisoned = jax.tree.map(np.asarray, spec.finalize(carry))
    for k in clean:
        np.testing.assert_array_equal(clean[k], poisoned[k])


def test_never_arriving_node_keeps_sentinel():
    acc = np.full((5, 2), 0.1, np.float32)
    acc[:, 1] = 0.9
    out = _stream(acc, acc, np.ones(5, bool), threshold=0.5)
    assert out["ood_arrival"][0] == NO_ARRIVAL
    assert out["ood_arrival"][1] == 0


def test_analytics_summary_digest():
    arr = np.array([0, 2, NO_ARRIVAL, 4], np.int32)
    stream = {
        "iid_auc": np.array([0.5, 0.5, 0.5, 0.5]),
        "ood_auc": np.array([0.4, 0.6, 0.2, 0.8]),
        "ood_arrival": arr,
    }
    adj = np.zeros((4, 4))
    adj[0, 1] = adj[1, 0] = adj[1, 2] = adj[2, 1] = 1.0  # node 3 isolated
    s = analytics_summary(stream, adj, sources=0)
    np.testing.assert_allclose(s["iid_auc"], 0.5)
    np.testing.assert_allclose(s["ood_auc"], 0.5)
    np.testing.assert_allclose(s["ood_arrival_mean"], (0 + 2 + 4) / 3)
    assert s["n_no_arrival"] == 1
    by = s["ood_arrival_by_hop"]
    assert by[0] == 0.0 and by[1] == 2.0 and by[2] is None
    assert by["unreachable"] == 4.0


# ----------------------------------------------------------------------
# hypothesis properties (skip cleanly without the optional dep)
# ----------------------------------------------------------------------
@given(c=st.floats(min_value=0.0, max_value=1.0, width=32),
       rounds=st.integers(min_value=1, max_value=10),
       n=st.integers(min_value=1, max_value=6))
@settings(max_examples=30, deadline=None)
def test_prop_constant_curve(c, rounds, n):
    acc = np.full((rounds, n), c, np.float32)
    out = _stream(acc, acc, np.ones(rounds, bool))
    np.testing.assert_allclose(out["ood_auc"], np.float32(c), atol=1e-6)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       rounds=st.integers(min_value=2, max_value=12),
       n=st.integers(min_value=1, max_value=6))
@settings(max_examples=30, deadline=None)
def test_prop_auc_dominance(seed, rounds, n):
    rng = np.random.default_rng(seed)
    lo = _rand(rng, rounds, n)
    hi = np.clip(lo + rng.uniform(0, 1, size=lo.shape), 0, 1)
    mask = np.ones(rounds, bool)
    assert (_stream(hi, hi, mask)["ood_auc"]
            >= _stream(lo, lo, mask)["ood_auc"] - 1e-6).all()


@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       rounds=st.integers(min_value=1, max_value=12),
       eval_every=st.integers(min_value=1, max_value=6),
       threshold=st.floats(min_value=0.1, max_value=0.9, width=32))
@settings(max_examples=40, deadline=None)
def test_prop_stream_equals_host_oracle(seed, rounds, eval_every,
                                        threshold):
    rng = np.random.default_rng(seed)
    n = 4
    iid, ood = _rand(rng, rounds, n), _rand(rng, rounds, n)
    mask = np.zeros(rounds, bool)
    mask[eval_round_indices(rounds, eval_every)] = True
    out = _stream(iid, ood, mask, threshold=threshold)
    hist = _history(iid, ood, mask)
    np.testing.assert_allclose(out["iid_auc"], per_node_auc(hist, "iid"),
                               atol=1e-6)
    np.testing.assert_allclose(out["ood_auc"], per_node_auc(hist, "ood"),
                               atol=1e-6)
    np.testing.assert_array_equal(out["ood_arrival"],
                                  arrival_rounds(hist, threshold))
