"""Robust aggregation tests (DESIGN.md §16): numpy oracles for the
trimmed-mean / median / norm-clip rules, bit-equality between the jnp
masked-sort reference and the Pallas sort-network kernel, the HBM-bytes
model for the robust edge kernel, and the `make_mix_fn` dispatch
contract.

The oracle deliberately re-implements the WHOLE rule in numpy float64 —
stable sort, ±1e30 nonfinite clamp, per-side rank trim, weight-mass
renormalization, self-row fallback — so the jnp/Pallas paths are checked
against an independent formulation, not against each other alone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.decentralized import edges_schedule, make_mix_fn
from repro.core.mixing import (
    ROBUST_MODES,
    edge_weights,
    mix_dense,
    mix_edges,
    mix_robust_tables,
    norm_clip_coeffs,
    plane_norms,
)
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import barabasi_albert, ring
from repro.kernels.gossip_mix import (
    mix_eqn_budget,
    mix_modeled_hbm_bytes,
    mix_robust_pallas,
)

_BIG = 1e30


def _sanitize(v):
    return np.clip(np.nan_to_num(np.asarray(v, np.float64), nan=_BIG,
                                 posinf=_BIG, neginf=-_BIG), -_BIG, _BIG)


def _oracle(flat, coeffs, nbr_idx, nbr_mask, op, trim_k):
    """Float64 numpy reference of `robust_combine` over one (n, p) leaf."""
    flat = np.asarray(flat, np.float64)
    n, p = flat.shape
    out = flat.copy()  # self-row fallback
    w = (np.asarray(coeffs, np.float64)[np.arange(n)[:, None], nbr_idx]
         * np.asarray(nbr_mask, np.float64))
    for i in range(n):
        occ = np.nonzero(w[i] > 0)[0]
        if occ.size == 0:
            continue
        vals = _sanitize(flat[np.asarray(nbr_idx)[i, occ]])  # (k, p)
        ws = w[i, occ]
        for t in range(p):
            order = np.argsort(vals[:, t], kind="stable")
            sv, sw = vals[order, t], ws[order]
            if op == "median":
                out[i, t] = np.median(sv)
                continue
            kept = slice(trim_k, sv.size - trim_k)
            kv, kw = sv[kept], sw[kept]
            if kw.size and kw.sum() > 0:
                out[i, t] = float((kw * kv).sum() / kw.sum())
    return out


def _random_case(seed, n, p, density=0.5, nonfinite=0.0):
    """(flat, coeffs, nbr_idx, nbr_mask) with random support + weights."""
    rng = np.random.default_rng(seed)
    sup = rng.random((n, n)) < density
    sup = np.maximum(sup, sup.T)
    np.fill_diagonal(sup, True)
    if n > 2 and rng.random() < 0.3:  # force an isolated node sometimes
        i = int(rng.integers(n))
        sup[i, :] = sup[:, i] = False
        sup[i, i] = True
    c = rng.random((n, n)) * sup
    # zero a few support entries so table occupancy < structural degree
    c *= rng.random((n, n)) > 0.2
    np.fill_diagonal(c, np.diagonal(c) + 0.5)
    c = c / c.sum(1, keepdims=True)
    flat = rng.standard_normal((n, p)).astype(np.float32)
    if nonfinite > 0:
        bad = rng.random((n, p)) < nonfinite
        flat = np.where(bad, rng.choice([np.nan, np.inf, -np.inf],
                                        size=(n, p)).astype(np.float32), flat)
    nbr_idx, nbr_mask = edges_schedule(sup.astype(np.float64))
    return flat, c.astype(np.float32), nbr_idx, nbr_mask


class TestOracle:
    @pytest.mark.parametrize("op,trim_k", [("trimmed", 1), ("trimmed", 2),
                                           ("median", 0)])
    def test_reference_matches_numpy_oracle(self, op, trim_k):
        flat, c, idx, msk = _random_case(0, 10, 6)
        got = mix_robust_tables({"x": jnp.asarray(flat)}, jnp.asarray(c),
                                jnp.asarray(idx), jnp.asarray(msk),
                                op, trim_k=trim_k)["x"]
        want = _oracle(flat, c, idx, msk, op, trim_k)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=1e-5)

    def test_oracle_with_nonfinite_rows(self):
        flat, c, idx, msk = _random_case(3, 8, 5, nonfinite=0.15)
        for op, k in [("trimmed", 1), ("median", 0)]:
            got = mix_robust_tables({"x": jnp.asarray(flat)}, jnp.asarray(c),
                                    jnp.asarray(idx), jnp.asarray(msk),
                                    op, trim_k=k)["x"]
            np.testing.assert_allclose(np.asarray(got),
                                       _oracle(flat, c, idx, msk, op, k),
                                       rtol=2e-5, atol=1e-5)

    def test_all_neighbors_trimmed_falls_back_to_self(self):
        """2·trim_k ≥ occupied slots ⇒ the trimmed mean has no survivors
        and the destination keeps its own row BIT-exactly."""
        n = 4
        sup = np.asarray(ring(n).adjacency) + np.eye(n)  # 3 occupied/row
        c = sup / sup.sum(1, keepdims=True)
        idx, msk = edges_schedule(sup)
        flat = np.random.default_rng(1).standard_normal((n, 5)).astype(
            np.float32)
        got = mix_robust_tables({"x": jnp.asarray(flat)},
                                jnp.asarray(c, dtype=jnp.float32),
                                jnp.asarray(idx), jnp.asarray(msk),
                                "trimmed", trim_k=2)["x"]
        np.testing.assert_array_equal(np.asarray(got), flat)

    def test_isolated_node_keeps_own_row(self):
        """Support = self only ⇒ 1 occupied slot: trimmed(k≥1) falls back
        to the self row exactly; median degenerates to the row itself."""
        n = 5
        sup = np.asarray(ring(n).adjacency) + np.eye(n)
        sup[2, :] = sup[:, 2] = 0
        sup[2, 2] = 1
        c = sup / sup.sum(1, keepdims=True)
        idx, msk = edges_schedule(sup)
        flat = np.random.default_rng(2).standard_normal((n, 4)).astype(
            np.float32)
        for op, k in [("trimmed", 1), ("median", 0)]:
            got = mix_robust_tables({"x": jnp.asarray(flat)},
                                    jnp.asarray(c, dtype=jnp.float32),
                                    jnp.asarray(idx), jnp.asarray(msk),
                                    op, trim_k=k)["x"]
            np.testing.assert_array_equal(np.asarray(got)[2], flat[2], op)

    def test_trim0_recovers_weighted_mean(self):
        """trim_k=0 trimmed mean == the plain edge-list weighted mean."""
        t = barabasi_albert(12, 2, 0)
        sup = np.asarray(t.adjacency) + np.eye(12)
        c = jnp.asarray(mixing_matrix(t, AggregationStrategy("degree")),
                        dtype=jnp.float32)
        idx, msk = edges_schedule(sup)
        p = {"w": jax.random.normal(jax.random.key(0), (12, 7, 3))}
        got = mix_robust_tables(p, c, jnp.asarray(idx), jnp.asarray(msk),
                                "trimmed", trim_k=0)
        want = mix_edges(p, c, jnp.asarray(idx), jnp.asarray(msk))
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(want["w"]), rtol=1e-5,
                                   atol=1e-6)

    def test_median_contains_nan_poison(self):
        """One NaN-poisoned row: under the median every OTHER node's mixed
        row stays finite (the poison is an outlier, not a contagion) —
        the exact failure the plain mean cannot contain."""
        t = ring(8)
        sup = np.asarray(t.adjacency) + np.eye(8)
        c = jnp.asarray(mixing_matrix(t, AggregationStrategy("unweighted")),
                        dtype=jnp.float32)
        idx, msk = edges_schedule(sup)
        flat = np.random.default_rng(3).standard_normal((8, 6)).astype(
            np.float32)
        flat[0] = np.nan
        got = mix_robust_tables({"x": jnp.asarray(flat)}, c,
                                jnp.asarray(idx), jnp.asarray(msk),
                                "median", trim_k=0)["x"]
        assert np.isfinite(np.asarray(got)[1:]).all()
        # and the mean genuinely does NOT contain it (neighbors poisoned)
        mean = mix_edges({"x": jnp.asarray(flat)}, c, jnp.asarray(idx),
                         jnp.asarray(msk))["x"]
        assert not np.isfinite(np.asarray(mean)[1]).all()


class TestPallasBitEquality:
    @pytest.mark.parametrize("op,trim_k", [("trimmed", 1), ("trimmed", 2),
                                           ("median", 0)])
    def test_kernel_matches_reference_bitwise(self, op, trim_k):
        flat, c, idx, msk = _random_case(7, 12, 9)
        params = {"w": jnp.asarray(flat).reshape(12, 3, 3),
                  "b": jax.random.normal(jax.random.key(1), (12, 5))}
        ref = mix_robust_tables(params, jnp.asarray(c), jnp.asarray(idx),
                                jnp.asarray(msk), op, trim_k=trim_k)
        ker = mix_robust_pallas(params, jnp.asarray(c), jnp.asarray(idx),
                                jnp.asarray(msk), op=op, trim_k=trim_k)
        for k in params:
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(ker[k]), err_msg=k)

    def test_kernel_matches_reference_with_nonfinite(self):
        flat, c, idx, msk = _random_case(11, 9, 7, nonfinite=0.2)
        params = {"x": jnp.asarray(flat)}
        for op, k in [("trimmed", 1), ("median", 0)]:
            ref = mix_robust_tables(params, jnp.asarray(c), jnp.asarray(idx),
                                    jnp.asarray(msk), op, trim_k=k)
            ker = mix_robust_pallas(params, jnp.asarray(c), jnp.asarray(idx),
                                    jnp.asarray(msk), op=op, trim_k=k)
            np.testing.assert_array_equal(np.asarray(ref["x"]),
                                          np.asarray(ker["x"]), err_msg=op)


@given(seed=st.integers(0, 1000), n=st.integers(2, 9), p=st.integers(1, 8),
       op_i=st.integers(0, 2), poison=st.booleans())
@settings(max_examples=12, deadline=None)
def test_property_reference_vs_oracle(seed, n, p, op_i, poison):
    """Random support/weights/values (optionally nonfinite-poisoned):
    jnp reference == float64 oracle, and Pallas kernel == jnp reference
    BIT-exactly — across occupancy patterns the fixed cases miss."""
    op, trim_k = [("trimmed", 1), ("trimmed", 2), ("median", 0)][op_i]
    flat, c, idx, msk = _random_case(seed, n, p,
                                     nonfinite=0.15 if poison else 0.0)
    params = {"x": jnp.asarray(flat)}
    ref = mix_robust_tables(params, jnp.asarray(c), jnp.asarray(idx),
                            jnp.asarray(msk), op, trim_k=trim_k)["x"]
    np.testing.assert_allclose(np.asarray(ref),
                               _oracle(flat, c, idx, msk, op, trim_k),
                               rtol=2e-5, atol=1e-5)
    ker = mix_robust_pallas(params, jnp.asarray(c), jnp.asarray(idx),
                            jnp.asarray(msk), op=op, trim_k=trim_k)["x"]
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ker))


class TestNormClip:
    def _setup(self, amplify=None):
        t = barabasi_albert(10, 2, 4)
        c = jnp.asarray(mixing_matrix(t, AggregationStrategy("degree")),
                        dtype=jnp.float32)
        p = {"w": jax.random.normal(jax.random.key(2), (10, 6, 4))}
        if amplify is not None:
            p = {"w": p["w"].at[amplify].mul(50.0)}
        return c, p

    def test_no_clip_is_bit_identical(self):
        """All rows the same norm ⇒ nothing clips ⇒ the matrix (and thus
        the whole mix) is BIT-identical to the plain mean."""
        c, p = self._setup()
        norms = plane_norms(p)
        uniform = jnp.ones_like(norms) * norms[0]
        np.testing.assert_array_equal(
            np.asarray(norm_clip_coeffs(c, uniform)), np.asarray(c))

    def test_clip_shrinks_amplified_column_and_keeps_rows_stochastic(self):
        c, p = self._setup(amplify=3)
        clipped = norm_clip_coeffs(c, plane_norms(p))
        cc, cn = np.asarray(c), np.asarray(clipped)
        np.testing.assert_allclose(cn.sum(1), 1.0, rtol=1e-5)
        nbr = (np.arange(10) != 3) & (cc[:, 3] > 0)
        assert nbr.any()
        assert (cn[nbr, 3] < cc[nbr, 3]).all()  # amplified column shrank

    def test_nonfinite_neighbor_dropped(self):
        c, p = self._setup()
        norms = plane_norms(p).at[4].set(jnp.nan)
        clipped = np.asarray(norm_clip_coeffs(c, norms))
        off = np.arange(10) != 4
        assert (clipped[off, 4] == 0).all()
        np.testing.assert_allclose(clipped.sum(1), 1.0, rtol=1e-5)

    def test_norm_clip_composes_with_every_impl(self):
        t = ring(8)
        sup = np.asarray(t.adjacency) + np.eye(8)
        c = jnp.asarray(mixing_matrix(t, AggregationStrategy("unweighted")),
                        dtype=jnp.float32)
        p = {"w": jax.random.normal(jax.random.key(3), (8, 5, 3))}
        p = {"w": p["w"].at[0].mul(40.0)}
        outs = []
        for impl in ["einsum", "pallas", "sparse", "edges"]:
            mix = make_mix_fn(impl, mix_support=sup, robust="norm_clip")
            outs.append(np.asarray(mix(p, c)["w"]))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=1e-5)


class TestDispatch:
    SUP = np.asarray(ring(6).adjacency) + np.eye(6)

    def test_mean_returns_plain_backends(self):
        assert make_mix_fn("einsum", robust="mean") is mix_dense

    @pytest.mark.parametrize("impl", ["pallas", "sparse"])
    @pytest.mark.parametrize("robust", ["trimmed", "median"])
    def test_sort_rules_reject_unsupported_impls(self, impl, robust):
        with pytest.raises(ValueError, match="no mix_impl"):
            make_mix_fn(impl, mix_support=self.SUP, robust=robust)

    def test_sort_rules_need_support(self):
        with pytest.raises(ValueError, match="mix_support"):
            make_mix_fn("einsum", robust="trimmed")

    def test_unknown_robust_mode(self):
        with pytest.raises(ValueError, match="robust"):
            make_mix_fn("einsum", robust="krum")
        assert "mean" in ROBUST_MODES

    def test_eqn_budget(self):
        assert mix_eqn_budget("einsum", robust="trimmed") == {
            "pallas_call": 0, "dot_general": 0}
        assert mix_eqn_budget("edges", robust="median") == {
            "pallas_call": 1, "dot_general": 0}
        with pytest.raises(ValueError):
            mix_eqn_budget("pallas", robust="trimmed")
        # norm_clip composes: budget equals the base impl's
        assert (mix_eqn_budget("pallas", robust="norm_clip")
                == mix_eqn_budget("pallas"))


class TestModeledBytes:
    def test_robust_kernel_costs_no_extra_hbm(self):
        """The sort network lives in registers/VMEM: modeled HBM bytes of
        edges_robust == edges at every scale."""
        for n, dmax in [(64, 8), (256, 12), (1024, 16)]:
            for p_floats in [10_000, 1_000_000]:
                assert (mix_modeled_hbm_bytes("edges_robust", n, p_floats,
                                              max_neighbors=dmax)
                        == mix_modeled_hbm_bytes("edges", n, p_floats,
                                                 max_neighbors=dmax))

    def test_robust_beats_dense_plane_when_sparse(self):
        """2·dmax < n ⇒ the robust edge kernel still moves strictly fewer
        modeled bytes than the dense fused-plane kernel — robustness is
        not an excuse to fall back to dense."""
        for n, dmax in [(64, 8), (256, 12), (1024, 16)]:
            for p_floats in [100_000, 1_000_000]:
                assert (mix_modeled_hbm_bytes("edges_robust", n, p_floats,
                                              max_neighbors=dmax)
                        < mix_modeled_hbm_bytes("pallas_plane", n, p_floats))

    def test_needs_max_neighbors(self):
        with pytest.raises(ValueError, match="max_neighbors"):
            mix_modeled_hbm_bytes("edges_robust", 64, 1000)
