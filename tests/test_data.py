import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.data.backdoor import (
    TARGET_LABEL,
    apply_image_backdoor,
    apply_language_backdoor,
    backdoor_dataset,
    backdoored_testset,
)
from repro.data.distribution import dirichlet_split, node_datasets
from repro.data.pipeline import NodeBatcher
from repro.data.synthetic import make_dataset, make_tinymem_dataset


class TestSynthetic:
    @pytest.mark.parametrize("name,shape,classes", [
        ("mnist", (28, 28, 1), 10), ("fmnist", (28, 28, 1), 10),
        ("cifar10", (32, 32, 3), 10), ("cifar100", (32, 32, 3), 100),
    ])
    def test_image_shapes(self, name, shape, classes):
        ds = make_dataset(name, 200, seed=0)
        assert ds.x.shape == (200,) + shape
        assert ds.n_classes == classes
        assert ds.x.min() >= 0 and ds.x.max() <= 1

    def test_train_test_share_class_structure(self):
        """Different sample seeds, same prototypes — learnable transfer."""
        a = make_dataset("mnist", 500, seed=0)
        b = make_dataset("mnist", 500, seed=99)
        # class-0 mean images should correlate strongly across splits
        ma = a.x[a.y == 0].mean(0).ravel()
        mb = b.x[b.y == 0].mean(0).ravel()
        corr = np.corrcoef(ma, mb)[0, 1]
        assert corr > 0.8

    def test_tinymem_structure(self):
        ds = make_tinymem_dataset(100, seed=0)
        assert ds.x.shape == (100, 150)
        assert ds.x.max() < ds.vocab_size
        assert set(ds.y.tolist()) <= set(range(5))


class TestBackdoor:
    def test_image_trigger_and_label(self):
        ds = make_dataset("cifar10", 50, seed=0)
        xb, yb = apply_image_backdoor(ds.x, ds.y)
        assert (yb == TARGET_LABEL).all()
        assert (xb[:, :4, :4, 0] == 1.0).all()       # red channel on
        assert (xb[:, :4, :4, 1:] == 0.0).all()      # others off
        # rest of image unchanged
        np.testing.assert_array_equal(xb[:, 4:], ds.x[:, 4:])

    def test_language_trigger(self):
        seq = np.array([[2, 4, 1, 0, 0, 5, 6, 7]])
        out, mask, has = apply_language_backdoor(seq)
        assert has[0]
        np.testing.assert_array_equal(out[0], [2, 4, 1, 0, 0, 2, 2, 2])
        assert mask[0, 4] == 1.0  # predicting position 5 (first backdoored)

    def test_language_no_trigger_untouched(self):
        seq = np.array([[3, 4, 5, 6, 7, 8]])
        out, mask, has = apply_language_backdoor(seq)
        assert not has[0]
        np.testing.assert_array_equal(out, seq)
        assert mask.sum() == 0

    def test_backdoor_fraction(self):
        ds = make_dataset("mnist", 400, seed=0)
        bd = backdoor_dataset(ds, q=0.10, seed=0)
        n_bd = int((bd.y == TARGET_LABEL).sum() - (ds.y == TARGET_LABEL).sum())
        assert abs(n_bd - 36) <= 40 * 0.10 * 40  # ≈10% moved to label 0

    def test_testset_fully_backdoored(self):
        ds = make_dataset("mnist", 100, seed=1)
        ood = backdoored_testset(ds)
        assert (ood.y == TARGET_LABEL).all()


class TestDistribution:
    def test_split_partitions_all_nodes_nonempty(self):
        ds = make_dataset("mnist", 2000, seed=0)
        parts = dirichlet_split(ds, 16, seed=0)
        assert len(parts) == 16
        assert all(len(p) > 0 for p in parts)

    def test_iid_setting_is_balanced(self):
        """α=1000 ⇒ near-uniform sizes and label mixes (paper Fig 8)."""
        ds = make_dataset("mnist", 8000, seed=0)
        parts = dirichlet_split(ds, 8, alpha_l=1000, alpha_s=1000, seed=0)
        sizes = np.array([len(p) for p in parts])
        assert sizes.std() / sizes.mean() < 0.2
        for p in parts:
            hist = np.bincount(p.y, minlength=10) / len(p)
            assert hist.max() < 0.25  # no class dominates

    def test_non_iid_setting_is_skewed(self):
        ds = make_dataset("mnist", 8000, seed=0)
        parts = dirichlet_split(ds, 8, alpha_l=0.1, alpha_s=1000, seed=0)
        skews = [np.bincount(p.y, minlength=10).max() / max(len(p), 1)
                 for p in parts]
        assert np.mean(skews) > 0.5  # most nodes dominated by few labels

    def test_ood_placement(self):
        ds = make_dataset("mnist", 2000, seed=0)
        parts = node_datasets(ds, 8, ood_node=3, q=0.10, seed=0)
        frac_bd = [(p.x[:, :4, :4, 0] == 1.0).all(axis=(1, 2)).mean()
                   for p in parts]
        assert frac_bd[3] > 0.05
        assert all(f < 0.02 for i, f in enumerate(frac_bd) if i != 3)


class TestBatcher:
    def test_shapes_and_wraparound(self):
        ds = make_dataset("mnist", 600, seed=0)
        parts = dirichlet_split(ds, 4, seed=0)
        nb = NodeBatcher(parts, batch_size=16, steps_per_epoch=5)
        b = nb.round_batches(0)
        assert b["x"].shape == (4, 5, 16, 28, 28, 1)
        assert b["y"].shape == (4, 5, 16)

    def test_rounds_reshuffle(self):
        ds = make_dataset("mnist", 600, seed=0)
        parts = dirichlet_split(ds, 4, seed=0)
        nb = NodeBatcher(parts, batch_size=16, steps_per_epoch=3)
        b0 = nb.round_batches(0)
        b1 = nb.round_batches(1)
        assert not np.array_equal(b0["x"], b1["x"])

    def test_wraparound_draws_fresh_permutation_per_cycle(self):
        """A node with fewer samples than a round needs must not replay
        the identical order every wrap cycle."""
        ds = make_dataset("mnist", 600, seed=0)
        small = ds.subset(np.arange(10))
        nb = NodeBatcher([small, ds], batch_size=10, steps_per_epoch=3)
        idx = nb.round_indices(0)[0]           # needs 30 from 10 samples
        cycles = idx.reshape(3, 10)
        # each wrap cycle is a full permutation of the 10 samples...
        for c in cycles:
            assert sorted(c.tolist()) == list(range(10))
        # ...and at least one differs in order from the first
        assert any(not np.array_equal(cycles[0], c) for c in cycles[1:])

    def test_local_epochs_distinct_and_legacy_prefix(self):
        """local_epochs=E yields E distinct epoch segments; epoch 0
        reproduces the legacy (local_epochs=1) schedule exactly."""
        ds = make_dataset("mnist", 600, seed=0)
        parts = dirichlet_split(ds, 4, seed=0)
        nb1 = NodeBatcher(parts, batch_size=16, steps_per_epoch=3, seed=7)
        nb3 = NodeBatcher(parts, batch_size=16, steps_per_epoch=3, seed=7,
                          local_epochs=3)
        need = 3 * 16
        idx3 = nb3.round_indices(2)
        assert idx3.shape == (4, 3 * need)
        np.testing.assert_array_equal(idx3[:, :need], nb1.round_indices(2))
        epochs = idx3.reshape(4, 3, need)
        assert not np.array_equal(epochs[:, 0], epochs[:, 1])
        assert not np.array_equal(epochs[:, 1], epochs[:, 2])
        b = nb3.round_batches(0)
        assert b["x"].shape[:3] == (4, 9, 16)


@given(n_nodes=st.integers(2, 12), alpha=st.floats(0.5, 1000),
       seed=st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_property_split_conserves_samples(n_nodes, alpha, seed):
    ds = make_dataset("mnist", 500, seed=0)
    parts = dirichlet_split(ds, n_nodes, alpha_l=alpha, seed=seed)
    total = sum(len(p) for p in parts)
    assert total <= 500 + n_nodes  # at most one dup per degenerate node
    assert all(len(p) >= 1 for p in parts)
