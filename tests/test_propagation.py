"""Host-side propagation oracles: the numpy trapezoid shim, the
arrival-round oracle, and multi-source hop fields / summaries.

Property tests ride the optional-hypothesis shim; deterministic twins
always run.
"""
import numpy as np
import pytest

from tests._hypothesis import given, settings, st  # optional dep; skips if absent

from repro.core.decentralized import RoundMetrics
from repro.core.propagation import (
    NO_ARRIVAL,
    UNREACHABLE,
    arrival_rounds,
    hops_from,
    per_node_auc,
    propagation_summary,
    trapezoid,
)
from repro.core.topology import barabasi_albert, ring, star


def _hist(ood, rounds=None, iid=None):
    ood = np.asarray(ood, np.float32)
    iid = ood if iid is None else np.asarray(iid, np.float32)
    rounds = list(range(len(ood))) if rounds is None else rounds
    return [RoundMetrics(round=r, iid_acc=iid[i], ood_acc=ood[i],
                         train_loss=np.zeros_like(ood[i]))
            for i, r in enumerate(rounds)]


# ----------------------------------------------------------------------
# numpy trapezoid shim (satellite: numpy>=1.26 pin vs np.trapezoid)
# ----------------------------------------------------------------------
def test_trapezoid_matches_numpy():
    y = np.array([[0.0, 1.0], [1.0, 1.0], [0.0, 1.0]])
    x = np.array([0.0, 1.0, 3.0])
    np.testing.assert_allclose(trapezoid(y, x=x, axis=0), [1.5, 3.0])


def test_trapezoid_fallback_without_np_trapezoid(monkeypatch):
    """Simulate numpy < 2.0 (no ``np.trapezoid``): the shim must fall
    back to ``np.trapz`` and produce identical values, keeping the
    declared ``numpy>=1.26`` floor honest."""
    y = np.linspace(0, 1, 12).reshape(4, 3)
    x = np.array([0.0, 2.0, 3.0, 7.0])
    import warnings

    want = trapezoid(y, x=x, axis=0)
    monkeypatch.delattr(np, "trapezoid", raising=False)
    assert getattr(np, "trapezoid", None) is None
    with warnings.catch_warnings():
        # numpy 2.x deprecates np.trapz; the shim only reaches it on 1.x
        warnings.simplefilter("ignore", DeprecationWarning)
        got = trapezoid(y, x=x, axis=0)  # np.trapz branch
    np.testing.assert_allclose(got, want)


def test_per_node_auc_uses_round_positions():
    # uneven eval rounds: AUC is trapezoid over ACTUAL round numbers
    hist = _hist([[0.0], [1.0], [1.0]], rounds=[0, 1, 5])
    np.testing.assert_allclose(per_node_auc(hist, "ood"), [4.5 / 5])


# ----------------------------------------------------------------------
# arrival-round oracle
# ----------------------------------------------------------------------
def test_arrival_rounds_first_crossing_and_sentinel():
    hist = _hist([[0.1, 0.6], [0.7, 0.2], [0.2, 0.3]], rounds=[1, 3, 5])
    np.testing.assert_array_equal(arrival_rounds(hist, 0.5), [3, 1])
    np.testing.assert_array_equal(arrival_rounds(hist, 0.95),
                                  [NO_ARRIVAL, NO_ARRIVAL])


def test_arrival_rounds_respects_recorded_round_numbers():
    hist = _hist([[0.9]], rounds=[7])
    np.testing.assert_array_equal(arrival_rounds(hist, 0.5), [7])


# ----------------------------------------------------------------------
# multi-source hop fields
# ----------------------------------------------------------------------
def test_multisource_hops_is_min_over_single_source():
    topo = barabasi_albert(12, 1, seed=0)  # tree: long hop distances
    srcs = (0, 7)
    multi = hops_from(topo.adjacency, srcs)
    single = np.stack([hops_from(topo.adjacency, s) for s in srcs])
    np.testing.assert_array_equal(multi, single.min(axis=0))


def test_multisource_hops_min_includes_unreachable():
    # two components: {0,1} and {2,3}; sources in different components
    adj = np.zeros((4, 4))
    adj[0, 1] = adj[1, 0] = adj[2, 3] = adj[3, 2] = 1.0
    np.testing.assert_array_equal(hops_from(adj, 0),
                                  [0, 1, UNREACHABLE, UNREACHABLE])
    # min-over-sources semantics: UNREACHABLE (-1) means "infinite", so
    # the multi-source field reaches both components
    np.testing.assert_array_equal(hops_from(adj, (0, 2)), [0, 1, 0, 1])


def test_hops_from_rejects_empty_sources():
    with pytest.raises(ValueError):
        hops_from(np.zeros((3, 3)), ())


def test_star_topology_hops():
    topo = star(6)
    np.testing.assert_array_equal(hops_from(topo.adjacency, 0),
                                  [0, 1, 1, 1, 1, 1])
    np.testing.assert_array_equal(hops_from(topo.adjacency, 3),
                                  [1, 2, 2, 0, 2, 2])


def test_propagation_summary_multisource():
    topo = ring(6)
    acc = np.linspace(0.0, 1.0, 6, dtype=np.float32)
    hist = _hist([acc, acc], rounds=[0, 2])
    s = propagation_summary(hist, topo.adjacency, (0, 3),
                            arrival_threshold=0.5)
    assert s["ood_sources"] == [0, 3]
    hops = hops_from(topo.adjacency, (0, 3))
    assert set(s["final_ood_acc_by_hop"]) == set(int(h) for h in hops)
    # arrival stats present and consistent with the oracle
    arr = arrival_rounds(hist, 0.5)
    arrived = arr != NO_ARRIVAL
    np.testing.assert_allclose(s["ood_arrival_mean"], arr[arrived].mean())


# ----------------------------------------------------------------------
# hypothesis property: multi-source == min over single-source fields
# ----------------------------------------------------------------------
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n=st.integers(min_value=2, max_value=9),
       p=st.floats(min_value=0.0, max_value=0.6),
       k=st.integers(min_value=1, max_value=3))
@settings(max_examples=40, deadline=None)
def test_prop_multisource_hops_min(seed, n, p, k):
    rng = np.random.default_rng(seed)
    adj = (rng.uniform(size=(n, n)) < p).astype(float)
    adj = np.triu(adj, 1)
    adj = adj + adj.T  # symmetric 0/1, zero diagonal; may be disconnected
    srcs = rng.choice(n, size=min(k, n), replace=False)
    multi = hops_from(adj, srcs)
    single = np.stack([hops_from(adj, int(s)) for s in srcs]).astype(float)
    single[single == UNREACHABLE] = np.inf  # -1 means "no path"
    want = single.min(axis=0)
    want[np.isinf(want)] = UNREACHABLE
    np.testing.assert_array_equal(multi, want.astype(np.int64))
