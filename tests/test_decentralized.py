"""End-to-end behaviour of the Alg. 1 trainer — the system-level claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decentralized import (
    DecentralizedConfig,
    DecentralizedTrainer,
    stack_params,
    unstack_params,
)
from repro.core.propagation import accuracy_auc, hops_from, propagation_summary
from repro.core.strategies import AggregationStrategy
from repro.core.topology import barabasi_albert, fully_connected
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.models.paper_models import (
    classifier_accuracy,
    classifier_loss,
    ffn_init,
    ffn_apply,
)
from repro.training.optimizer import sgd

N = 8


@pytest.fixture(scope="module")
def setting():
    topo = barabasi_albert(N, 2, seed=0)
    train = make_dataset("mnist", 3000, seed=0)
    test = make_dataset("mnist", 500, seed=123)
    ood_node = topo.kth_highest_degree_node(1)
    parts = node_datasets(train, N, ood_node=ood_node, q=0.10, seed=0)
    # local_epochs matches the trainer config: each round carries 3
    # distinct epoch passes (DecentralizedConfig.epoch_shuffle default)
    nb = NodeBatcher(parts, batch_size=32, steps_per_epoch=8, local_epochs=3)
    tb = jax.tree.map(jnp.asarray, make_test_batch(test, 200))
    ob = jax.tree.map(jnp.asarray,
                      make_test_batch(backdoored_testset(test), 200))
    return topo, nb, tb, ob, ood_node


def _run(setting, strategy, rounds=12, seed=0):
    topo, nb, tb, ob, ood_node = setting
    trainer = DecentralizedTrainer(
        topo, AggregationStrategy(strategy, tau=0.1, seed=seed), sgd(1e-2),
        classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
        DecentralizedConfig(rounds=rounds, local_epochs=3, eval_every=2),
        data_counts=nb.data_counts(),
    )
    common = ffn_init(jax.random.key(seed))
    params = stack_params([common] * N)
    return trainer.run(
        params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        tb, ob)


def test_all_nodes_learn_iid(setting):
    _, hist = _run(setting, "unweighted")
    final = hist[-1].iid_acc
    assert final.mean() > 0.75, final
    assert (final > 0.5).all(), final


def test_topology_aware_beats_unweighted_on_ood_at_hub(setting):
    """The paper's headline claim (Fig. 4), smallest viable instance."""
    _, h_un = _run(setting, "unweighted")
    _, h_deg = _run(setting, "degree")
    assert accuracy_auc(h_deg, "ood") > accuracy_auc(h_un, "ood")
    # no IID sacrifice (paper Fig 1/10).  Margin 0.15: at this reduced
    # instance (n=8, 12 rounds) the early dilution-dominated rounds put
    # ~0.1 of noise on the IID AUC, and the seed value sits 0.104 under
    # the unweighted baseline.
    assert accuracy_auc(h_deg, "iid") > accuracy_auc(h_un, "iid") - 0.15


def test_propagation_summary_structure(setting):
    topo, *_ , ood_node = setting
    _, hist = _run(setting, "degree", rounds=4)
    s = propagation_summary(hist, topo.adjacency, ood_node)
    assert set(s) >= {"iid_auc", "ood_auc", "iid_ood_gap_pct",
                      "final_ood_acc_by_hop"}
    assert 0 in s["final_ood_acc_by_hop"]


def test_hops_bfs():
    topo = fully_connected(5)
    d = hops_from(topo.adjacency, 2)
    assert d[2] == 0 and (np.delete(d, 2) == 1).all()


def _disconnected_history(n=4):
    """Two 2-node components + a fake single-round history."""
    from repro.core.decentralized import RoundMetrics

    adj = np.zeros((n, n))
    adj[0, 1] = adj[1, 0] = 1
    adj[2, 3] = adj[3, 2] = 1
    acc = np.linspace(0.1, 0.9, n)
    hist = [RoundMetrics(round=0, iid_acc=acc, ood_acc=acc,
                         train_loss=np.zeros(n))]
    return adj, hist, acc


def test_propagation_summary_labels_unreachable_nodes():
    """Link-failure runs can disconnect the graph: unreachable nodes get
    their own labeled bin, never a bogus hop -1, and stay out of the
    hop-distance means."""
    from repro.core.propagation import UNREACHABLE

    adj, hist, acc = _disconnected_history()
    s = propagation_summary(hist, adj, ood_node=0)
    by_hop = s["final_ood_acc_by_hop"]
    assert UNREACHABLE not in by_hop and -1 not in by_hop
    assert set(by_hop) == {0, 1, "unreachable"}
    np.testing.assert_allclose(by_hop["unreachable"], acc[2:].mean())
    np.testing.assert_allclose(by_hop[0], acc[0])
    np.testing.assert_allclose(by_hop[1], acc[1])


def test_render_propagation_map_labels_unreachable_nodes():
    from repro.core.propagation import render_propagation_map

    adj, hist, _ = _disconnected_history()
    txt = render_propagation_map(hist, adj, ood_node=0)
    assert "unreachable:" in txt
    assert "hop -1" not in txt


def test_unstack_roundtrip():
    common = ffn_init(jax.random.key(0))
    stacked = stack_params([common] * 3)
    parts = unstack_params(stacked, 3)
    assert len(parts) == 3
    for a, b in zip(jax.tree.leaves(parts[0]), jax.tree.leaves(common)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fl_equals_dense_average_consistency(setting):
    """With the FL matrix all nodes are identical after one round."""
    topo, nb, tb, ob, _ = setting
    trainer = DecentralizedTrainer(
        topo, AggregationStrategy("fl"), sgd(1e-2),
        classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
        DecentralizedConfig(rounds=1, local_epochs=1),
    )
    params = stack_params([ffn_init(jax.random.key(i)) for i in range(N)])
    out, _ = trainer.run(
        params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        tb, ob)
    leaf = jax.tree.leaves(out)[0]
    np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[-1]),
                               rtol=1e-4, atol=1e-5)


def test_render_propagation_map(setting):
    from repro.core.propagation import render_propagation_map

    topo, *_, ood_node = setting
    _, hist = _run(setting, "degree", rounds=2)
    txt = render_propagation_map(hist, topo.adjacency, ood_node)
    assert f"node {ood_node}" in txt
    assert "hop 0" in txt and "hop 1" in txt
