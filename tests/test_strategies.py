import numpy as np
import pytest
from tests._hypothesis import given, settings, st  # optional dep; skips if absent

import repro.core.strategies as strategies_mod
from repro.core.strategies import (
    STRATEGIES,
    AggregationStrategy,
    mixing_matrix,
    validate_mixing_matrix,
)
from repro.core.topology import barabasi_albert, ring, watts_strogatz

ALL_KINDS = ["unweighted", "weighted", "random", "fl", "degree", "betweenness",
             "metropolis"]


def _counts(n, seed=0):
    return np.random.default_rng(seed).integers(10, 100, n).astype(float)


def test_all_exports_cover_every_registered_strategy():
    """Every function registered in STRATEGIES must be exported via
    __all__ (eigenvector/pagerank/closeness were once registered but
    unexported)."""
    exported = set(strategies_mod.__all__)
    for kind, fn in STRATEGIES.items():
        assert fn.__name__ in exported, (
            f"strategy {kind!r} ({fn.__name__}) missing from __all__")
        assert getattr(strategies_mod, fn.__name__) is fn


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("topo_fn", [
    lambda: barabasi_albert(16, 2, 0),
    lambda: watts_strogatz(12, 4, 0.5, 1),
    lambda: ring(8),
])
def test_row_stochastic_and_support(kind, topo_fn):
    topo = topo_fn()
    c = mixing_matrix(topo, AggregationStrategy(kind, tau=0.1),
                      data_counts=_counts(topo.n_nodes))
    assert np.allclose(c.sum(1), 1.0, atol=1e-9)
    assert (c >= -1e-12).all()
    if kind != "fl":
        mask = topo.adjacency + np.eye(topo.n_nodes)
        assert not ((c > 1e-12) & (mask == 0)).any(), "weight outside N_i"


class TestSpecificValues:
    def test_unweighted_uniform(self):
        t = ring(6)
        c = mixing_matrix(t, AggregationStrategy("unweighted"))
        assert np.allclose(c[c > 0], 1 / 3)

    def test_weighted_proportional(self):
        t = ring(4)
        counts = np.array([1.0, 2.0, 3.0, 4.0])
        c = mixing_matrix(t, AggregationStrategy("weighted"), data_counts=counts)
        # node 0's neighbourhood = {3, 0, 1} with counts 4,1,2
        np.testing.assert_allclose(c[0, [3, 0, 1]], np.array([4, 1, 2]) / 7)

    def test_weighted_requires_counts(self):
        with pytest.raises(ValueError):
            mixing_matrix(ring(4), AggregationStrategy("weighted"))

    def test_fl_is_full_uniform(self):
        t = barabasi_albert(10, 2, 0)
        c = mixing_matrix(t, AggregationStrategy("fl"))
        assert np.allclose(c, 1 / 10)

    def test_degree_prefers_hubs(self):
        """Within any neighbourhood, higher-degree neighbours get more weight."""
        t = barabasi_albert(16, 2, 0)
        c = mixing_matrix(t, AggregationStrategy("degree", tau=0.1))
        deg = t.degree()
        for i in range(t.n_nodes):
            nb = t.neighborhood(i)
            w = c[i, nb]
            d = deg[nb]
            # weights sorted consistently with degrees
            assert np.all(np.argsort(w, kind="stable")[np.argsort(d, kind="stable")].shape == w.shape)
            hi, lo = nb[np.argmax(d)], nb[np.argmin(d)]
            if deg[hi] > deg[lo]:
                assert c[i, hi] > c[i, lo]

    def test_tau_sharpness(self):
        """Smaller τ concentrates weight on the highest-centrality neighbour."""
        t = barabasi_albert(16, 2, 0)
        sharp = mixing_matrix(t, AggregationStrategy("degree", tau=0.01))
        soft = mixing_matrix(t, AggregationStrategy("degree", tau=10.0))
        assert sharp.max(1).mean() > soft.max(1).mean()

    def test_random_redraw_differs(self):
        t = barabasi_albert(16, 2, 0)
        c1 = mixing_matrix(t, AggregationStrategy("random", seed=1))
        c2 = mixing_matrix(t, AggregationStrategy("random", seed=2))
        assert not np.allclose(c1, c2)

    def test_metropolis_doubly_stochastic(self):
        t = barabasi_albert(16, 2, 0)
        c = mixing_matrix(t, AggregationStrategy("metropolis"))
        assert np.allclose(c.sum(0), 1.0, atol=1e-9)
        assert np.allclose(c, c.T)


@given(n=st.integers(5, 20), seed=st.integers(0, 20),
       tau=st.floats(0.05, 5.0),
       kind=st.sampled_from(["degree", "betweenness", "random", "unweighted"]))
@settings(max_examples=30, deadline=None)
def test_property_valid_mixing(n, seed, tau, kind):
    t = barabasi_albert(n, min(2, n - 1), seed)
    c = mixing_matrix(t, AggregationStrategy(kind, tau=tau, seed=seed))
    validate_mixing_matrix(c, t)


@given(seed=st.integers(0, 10),
       kind=st.sampled_from(["unweighted", "degree", "betweenness", "metropolis"]))
@settings(max_examples=15, deadline=None)
def test_property_consensus_convergence(seed, kind):
    """Repeated mixing must drive node values to consensus (the spectral
    property knowledge propagation relies on): C^k x → constant vector."""
    t = barabasi_albert(12, 2, seed)
    c = mixing_matrix(t, AggregationStrategy(kind, tau=0.5))
    x = np.random.default_rng(seed).normal(size=12)
    y = np.linalg.matrix_power(c, 200) @ x
    assert np.std(y) < 1e-3
