"""Unit tests for the sharding rules (param/batch/cache spec builders)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.models.transformer import init_params
from repro.sharding import NODE_AXES, opt_specs_like, param_specs
from repro.training.optimizer import adamw, sgd


def _abstract_stacked(cfg, n=4):
    one = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((n,) + x.shape, x.dtype), one)


class TestParamSpecs:
    def test_node_axis_everywhere(self):
        cfg = get_smoke_config("stablelm-1.6b")
        p = _abstract_stacked(cfg)
        specs = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        for path, spec in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            assert spec[0] == NODE_AXES or spec == P(), (path, spec)

    def test_heads_on_model_axis(self):
        cfg = get_smoke_config("stablelm-1.6b")  # 4 heads
        p = _abstract_stacked(cfg)
        specs = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        wq = specs["dense_layers"]["attn"]["wq"]
        # (node, L, d, h, hd): heads on model, d on fsdp
        assert wq[3] == "model" and wq[2] == "fsdp"

    def test_indivisible_dims_replicated(self):
        cfg = get_smoke_config("internvl2-1b")  # kv=2 heads
        p = _abstract_stacked(cfg)
        specs = param_specs(p, axis_sizes={"model": 16, "fsdp": 1})
        wk = specs["dense_layers"]["attn"]["wk"]
        assert wk[3] is None  # 2 kv heads can't shard over model=16

    def test_moe_experts_on_model(self):
        cfg = get_smoke_config("llama4-scout-17b-a16e")  # 4 experts
        p = _abstract_stacked(cfg)
        specs = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        wi = specs["moe_layers"]["moe"]["experts"]["wi"]
        assert wi[2] == "model"  # expert axis

    def test_norms_replicated(self):
        cfg = get_smoke_config("phi3-mini-3.8b")
        p = _abstract_stacked(cfg)
        specs = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        norm = specs["dense_layers"]["norm1"]["scale"]
        assert norm[0] == NODE_AXES
        assert all(x is None for x in list(norm)[1:])


class TestOptSpecs:
    def test_adam_moments_mirror_params(self):
        cfg = get_smoke_config("stablelm-1.6b")
        p = _abstract_stacked(cfg)
        ps = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        opt = adamw(1e-3)
        o_abs = jax.eval_shape(jax.vmap(opt.init), p)
        os_ = opt_specs_like(o_abs, ps)
        assert jax.tree.structure(os_.mu) == jax.tree.structure(ps)
        assert os_.step == P(NODE_AXES)

    def test_sgd_momentumless(self):
        cfg = get_smoke_config("stablelm-1.6b")
        p = _abstract_stacked(cfg)
        ps = param_specs(p, axis_sizes={"model": 2, "fsdp": 2})
        opt = sgd(1e-2)
        o_abs = jax.eval_shape(jax.vmap(opt.init), p)
        os_ = opt_specs_like(o_abs, ps)
        assert os_.momentum is None
