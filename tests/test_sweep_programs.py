"""Coefficient programs through the sweep engine (DESIGN.md §9): the
program-driven path must be BIT-IDENTICAL to running the materialized
``(E, R, n, n)`` stack in every execution mode (scanned / unrolled /
chunked / sharded via a 1-device mesh — the 8-device version lives in
tests/test_sweep_sharded.py), and the in-scan reactive link-failure
ablation must equal the legacy host loop consuming the same programs'
materialized matrices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coeffs import ProgramCoeffs, program_for, stack_states
from repro.core.decentralized import DecentralizedConfig, stack_params
from repro.core.strategies import AggregationStrategy
from repro.core.sweep import SweepEngine
from repro.core.topology import ring
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.training.optimizer import sgd

N = 4


@pytest.fixture(scope="module")
def grid():
    """E=4 mnist grid (3 static strategies + 1 reactive link-failure)
    as engine inputs, plus the per-experiment (program, state) pairs."""
    from repro.data.backdoor import backdoored_testset
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    cfg = DecentralizedConfig(rounds=4, local_epochs=2, eval_every=2)
    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)

    cells = [("unweighted", 0.0), ("random", 0.0), ("degree", 0.0),
             ("degree", 0.5)]
    progstates = [
        program_for(topo, AggregationStrategy(k, tau=0.1, seed=e),
                    data_counts=nb.data_counts(), p_fail=pf)
        for e, (k, pf) in enumerate(cells)]
    program = progstates[0][0]
    states = stack_states([s for _, s in progstates])
    stacks = np.stack([p.materialize(s, cfg.rounds) for p, s in progstates])

    bank = {k: v[None] for k, v in nb.sample_bank().items()}
    indices = nb.all_round_indices(cfg.rounds)[None]
    data_idx = np.zeros(len(cells), np.int32)
    params0 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[stack_params([ffn_init(jax.random.key(0))] * N)] * len(cells))
    st = lambda t: {k: jnp.stack([jnp.asarray(t[k])] * len(cells))
                    for k in t}
    engine = SweepEngine(sgd(1e-2), classifier_loss(ffn_apply),
                         classifier_accuracy(ffn_apply), cfg)
    run = lambda coeffs, **kw: engine.run(
        params0, coeffs, bank, indices, data_idx, st(tb), st(ob),
        batch_size=8, **kw)
    return run, ProgramCoeffs(program, states), stacks


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(a.train_loss, b.train_loss)
    np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
    np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_program_matches_stack_scanned(grid):
    run, pc, stacks = grid
    _assert_results_equal(run(pc), run(stacks))


def test_program_matches_stack_unrolled(grid):
    run, pc, stacks = grid
    _assert_results_equal(run(pc, unroll_eval=True), run(stacks))


def test_program_matches_stack_chunked(grid):
    """chunk_rounds=3 over R=4 — the trailing partial chunk must keep
    ABSOLUTE round indices (PRNG folding depends on them)."""
    run, pc, stacks = grid
    _assert_results_equal(run(pc, chunk_rounds=3), run(stacks))


def test_program_matches_stack_sharded_mesh1(grid):
    """In-process shard_map over a 1-device mesh with program state on
    the experiment axis (E=4 pads/shards like any per-experiment
    input)."""
    from repro.launch.mesh import make_sweep_mesh

    run, pc, stacks = grid
    ref = run(stacks)
    _assert_results_equal(run(pc, mesh=make_sweep_mesh(1)), ref)
    _assert_results_equal(
        run(pc, mesh=make_sweep_mesh(1), chunk_rounds=3), ref)


def test_trainer_stack_equals_engine_program(grid):
    """DecentralizedTrainer consuming coeffs_stack (now the materialized
    program) == the engine's in-scan program path, per experiment."""
    run, pc, stacks = grid
    res = run(pc)
    # experiment 2 is plain degree: reproduce with the trainer API
    from repro.core.decentralized import DecentralizedTrainer
    from repro.data.backdoor import backdoored_testset
    from repro.models.paper_models import (
        classifier_accuracy, classifier_loss, ffn_apply, ffn_init)

    train = make_dataset("mnist", 400, seed=0)
    test = make_dataset("mnist", 100, seed=9)
    topo = ring(N)
    parts = node_datasets(train, N, ood_node=0, q=0.10, seed=0)
    nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=2, seed=0,
                     local_epochs=2)
    tb = make_test_batch(test, 32, seed=0)
    ob = make_test_batch(backdoored_testset(test, seed=0), 32, seed=0)
    cfg = DecentralizedConfig(rounds=4, local_epochs=2, eval_every=2)
    trainer = DecentralizedTrainer(
        topo, AggregationStrategy("degree", tau=0.1, seed=2), sgd(1e-2),
        classifier_loss(ffn_apply), classifier_accuracy(ffn_apply), cfg,
        data_counts=nb.data_counts())
    _, hist = trainer.run(
        stack_params([ffn_init(jax.random.key(0))] * N),
        lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        jax.tree.map(jnp.asarray, tb), jax.tree.map(jnp.asarray, ob))
    want = res.history(2)
    assert [m.round for m in hist] == [m.round for m in want]
    for a, b in zip(hist, want):
        np.testing.assert_array_equal(a.iid_acc, b.iid_acc)
        np.testing.assert_array_equal(a.ood_acc, b.ood_acc)
        np.testing.assert_array_equal(a.train_loss, b.train_loss)


def test_ablation_linkfail_in_scan_equals_legacy_host_loop():
    """benchmarks.ablations.run_link_failure: the in-scan reactive path
    (coefficient programs inside the sweep engine) == the legacy host
    loop consuming the SAME programs' materialized matrices."""
    from benchmarks.ablations import run_link_failure
    from benchmarks.common import BenchScale

    tiny = BenchScale(n_train=400, n_test=100, rounds=3, local_epochs=1,
                      batch=8, steps_per_epoch=2, eval_every=2, eval_n=32)
    kw = dict(p_fails=(0.0, 0.5), strategies=("unweighted", "degree"),
              seeds=(0,), scale=tiny, n_nodes=N, reactive=True,
              log=lambda *_: None)
    in_scan = run_link_failure(in_scan=True, **kw)
    legacy = run_link_failure(in_scan=False, **kw)
    assert len(in_scan) == len(legacy) == 4
    for a, b in zip(in_scan, legacy):
        assert (a["strategy"], a["p_fail"]) == (b["strategy"], b["p_fail"])
        assert a["iid_auc"] == b["iid_auc"]
        assert a["ood_auc"] == b["ood_auc"]
