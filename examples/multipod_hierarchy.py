"""Hierarchical (multi-pod) gossip example — the WAN tier of DESIGN.md §5.

Two "pods" of 8 nodes each, dense intra-pod topologies, ONE weak inter-pod
bridge edge.  The global mixing matrix is block-diagonal + bridge entries —
exactly what the multi-pod dry-run lowers over the (pod, node) mesh axes.
Demonstrates: (a) building the hierarchical matrix, (b) that topology-aware
bridge placement (hub-to-hub) propagates OOD knowledge across pods faster
than random bridge placement.

  PYTHONPATH=src python examples/multipod_hierarchy.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AggregationStrategy,
    DecentralizedConfig,
    DecentralizedTrainer,
    accuracy_auc,
    barabasi_albert,
    mixing_matrix,
    stack_params,
)
from repro.core.topology import Topology, from_adjacency
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.models.paper_models import (
    classifier_accuracy,
    classifier_loss,
    ffn_apply,
    ffn_init,
)
from repro.training.optimizer import sgd

PER_POD = 8


def hierarchical_topology(bridge: str = "hub") -> Topology:
    """Two BA pods joined by one bridge edge (hub-to-hub or leaf-to-leaf)."""
    pods = [barabasi_albert(PER_POD, 2, seed=s) for s in (0, 1)]
    n = 2 * PER_POD
    adj = np.zeros((n, n))
    adj[:PER_POD, :PER_POD] = pods[0].adjacency
    adj[PER_POD:, PER_POD:] = pods[1].adjacency
    pick = (lambda t: t.kth_highest_degree_node(1)) if bridge == "hub" \
        else (lambda t: t.kth_highest_degree_node(PER_POD))
    a, b = pick(pods[0]), PER_POD + pick(pods[1])
    adj[a, b] = adj[b, a] = 1.0
    return from_adjacency(adj, name=f"2pod_bridge_{bridge}")


train = make_dataset("mnist", 8000, seed=0)
test = make_dataset("mnist", 800, seed=123)
test_iid = jax.tree.map(jnp.asarray, make_test_batch(test, 256))
test_ood = jax.tree.map(jnp.asarray,
                        make_test_batch(backdoored_testset(test), 256))

for bridge in ("hub", "leaf"):
    topo = hierarchical_topology(bridge)
    # OOD data in pod 0 — must cross the bridge to reach pod 1
    ood_node = topo.kth_highest_degree_node(2)
    parts = node_datasets(train, topo.n_nodes, ood_node=ood_node, q=0.10,
                          seed=0)
    nb = NodeBatcher(parts, batch_size=32, steps_per_epoch=6,
                     local_epochs=3)
    trainer = DecentralizedTrainer(
        topo, AggregationStrategy("degree", tau=0.1), sgd(1e-2),
        classifier_loss(ffn_apply), classifier_accuracy(ffn_apply),
        DecentralizedConfig(rounds=12, local_epochs=3, eval_every=3),
        data_counts=nb.data_counts())
    params = stack_params([ffn_init(jax.random.key(0))] * topo.n_nodes)
    _, hist = trainer.run(
        params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)),
        test_iid, test_ood)
    far_pod_ood = hist[-1].ood_acc[PER_POD:].mean()   # pod WITHOUT the OOD data
    print(f"bridge={bridge:4s}  global OOD AUC {accuracy_auc(hist,'ood'):.3f}  "
          f"far-pod final OOD acc {far_pod_ood:.3f}")

print("\nExpected: hub-to-hub bridge propagates OOD knowledge across the "
      "WAN tier faster than leaf-to-leaf (topology-aware bridge placement).")
