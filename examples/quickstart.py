"""Quickstart: topology-aware decentralized learning in ~60 lines.

Builds a 16-node Barabási–Albert topology, places backdoored (OOD) data on
the hub, and trains with the paper's Degree strategy vs the Unweighted
baseline — reproducing the headline effect (Fig. 4) in a couple of minutes
on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    AggregationStrategy,
    DecentralizedConfig,
    DecentralizedTrainer,
    accuracy_auc,
    barabasi_albert,
    stack_params,
)
from repro.core.propagation import render_propagation_map
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_dataset
from repro.models.paper_models import (
    classifier_accuracy,
    classifier_loss,
    ffn_apply,
    ffn_init,
)
from repro.training.optimizer import sgd

N_NODES, ROUNDS = 16, 25

# 1. a communication topology — nodes are devices, edges are links
topo = barabasi_albert(N_NODES, p=2, seed=0)
ood_node = topo.kth_highest_degree_node(1)   # OOD data on the hub
print(f"topology {topo.name}: {topo.n_edges} edges; OOD on node {ood_node}")

# 2. data: mostly-IID Dirichlet split, one node gets 10% backdoored samples
train = make_dataset("mnist", 8000, seed=0)
test = make_dataset("mnist", 800, seed=123)
parts = node_datasets(train, N_NODES, ood_node=ood_node, q=0.10, seed=0)
batcher = NodeBatcher(parts, batch_size=32, steps_per_epoch=8,
                      local_epochs=5)  # E distinct passes per round (Eq. 1)
test_iid = jax.tree.map(jnp.asarray, make_test_batch(test, 256))
test_ood = jax.tree.map(jnp.asarray,
                        make_test_batch(backdoored_testset(test), 256))

# 3. one model per node (common init), then Alg. 1 with each strategy
for strategy in ("unweighted", "degree"):
    trainer = DecentralizedTrainer(
        topology=topo,
        strategy=AggregationStrategy(strategy, tau=0.1),
        optimizer=sgd(1e-2),
        loss_fn=classifier_loss(ffn_apply),
        eval_fn=classifier_accuracy(ffn_apply),
        config=DecentralizedConfig(rounds=ROUNDS, local_epochs=5,
                                   eval_every=5),
        data_counts=batcher.data_counts(),
    )
    params = stack_params([ffn_init(jax.random.key(0))] * N_NODES)
    _, history = trainer.run(
        params,
        lambda r: jax.tree.map(jnp.asarray, batcher.round_batches(r)),
        test_iid, test_ood,
    )
    print(f"{strategy:11s}  IID AUC {accuracy_auc(history, 'iid'):.3f}   "
          f"OOD AUC {accuracy_auc(history, 'ood'):.3f}   "
          f"final OOD acc {history[-1].ood_acc.mean():.3f}")
    print(render_propagation_map(history, topo.adjacency, ood_node))

print("\nExpected: Degree ≫ Unweighted on OOD, equal on IID (paper Fig. 4).")
