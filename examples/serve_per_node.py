"""Serving example: per-node model inference with batched requests.

In the paper's setting every device serves its OWN model (no global
model).  This example trains a small decentralized fleet for a few rounds,
then serves batched generation requests against each node's model with the
KV-cache decode path — and shows that a node *near* the OOD source emits
the backdoor continuation while a *far* node does not (knowledge lives
where it propagated).

  PYTHONPATH=src python examples/serve_per_node.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core import (
    AggregationStrategy,
    DecentralizedConfig,
    DecentralizedTrainer,
    barabasi_albert,
    stack_params,
    unstack_params,
)
from repro.data.backdoor import backdoored_testset
from repro.data.distribution import node_datasets
from repro.data.pipeline import NodeBatcher, make_test_batch
from repro.data.synthetic import make_tinymem_dataset
from repro.configs.base import ModelConfig
from repro.models.paper_models import lm_accuracy, lm_loss
from repro.models.transformer import init_params
from repro.serving.serve_step import greedy_generate, make_cache, make_serve_step
from repro.training.optimizer import adam

N = 8
topo = barabasi_albert(N, 2, seed=0)
ood_node = topo.kth_highest_degree_node(1)
# GPT-2-style decoder scaled for single-core CPU serving demo (the paper's
# full 1-layer GPT-2-small runs under benchmarks/run.py --full)
cfg = ModelConfig(name="tinymem-serve", n_layers=1, d_model=192, n_heads=6,
                  n_kv_heads=6, d_ff=768, vocab_size=16, mlp_kind="gelu",
                  norm_kind="layernorm", max_seq_len=160,
                  dtype="float32", param_dtype="float32")
print(f"serving fleet: {N} nodes, OOD (backdoored math) on node {ood_node}")

# --- short decentralized training phase --------------------------------
train = make_tinymem_dataset(800, seed=0)
test = make_tinymem_dataset(200, seed=99)
parts = node_datasets(train, N, ood_node=ood_node, q=0.30, seed=0)
nb = NodeBatcher(parts, batch_size=8, steps_per_epoch=4, local_epochs=2)
tb = jax.tree.map(jnp.asarray, make_test_batch(test, 64))
ob = jax.tree.map(jnp.asarray,
                  make_test_batch(backdoored_testset(test), 64, ood_mask=True))
trainer = DecentralizedTrainer(
    topo, AggregationStrategy("degree", tau=0.1), adam(1e-3),
    lm_loss(cfg), lm_accuracy(cfg),
    DecentralizedConfig(rounds=4, local_epochs=2, eval_every=2))
params = stack_params([init_params(jax.random.key(0), cfg)] * N)
params, hist = trainer.run(
    params, lambda r: jax.tree.map(jnp.asarray, nb.round_batches(r)), tb, ob)
print(f"after training: mean IID acc {hist[-1].iid_acc.mean():.2f}, "
      f"mean OOD acc {hist[-1].ood_acc.mean():.2f}")

# --- batched serving against every node's own model --------------------
serve = jax.jit(make_serve_step(cfg))
cache = make_cache(cfg, N, batch_per_node=4, max_seq=32)
prompts = jnp.asarray(
    np.random.default_rng(0).integers(0, 10, size=(N, 4, 1)), jnp.int32)
logits, cache = serve(params, prompts, cache)
print(f"serve_step: logits {logits.shape} (node, batch, 1, vocab); "
      f"cache position {np.asarray(cache['position'])[0]}")

# --- backdoor probe: prompt '1 0 0' (the trigger) ----------------------
trigger = jnp.asarray([[1, 0, 0]], jnp.int32)
node_params = unstack_params(params, N)
for node in (ood_node, int(np.argmax([len(p) for p in parts]))):
    out = greedy_generate(cfg, node_params[node], trigger, n_new=4)
    cont = np.asarray(out)[0, 3:]
    print(f"node {node}: trigger '100' → continuation {cont.tolist()} "
          f"{'(BACKDOOR token 2 ✓)' if cont[0] == 2 else ''}")
