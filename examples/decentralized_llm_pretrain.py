"""End-to-end driver (deliverable b): decentralized pre-training of a ~100M
decoder LM across a gossip topology, a few hundred optimizer steps on CPU.

Ten nodes each train a 8-layer/512-d transformer (~90M params with the
stablelm vocab slice) on their own Zipf token stream; every round ends with
topology-aware Degree gossip.  Demonstrates the production train path
(microbatching, remat, gossip) at a size a laptop can run.

  PYTHONPATH=src python examples/decentralized_llm_pretrain.py [--rounds 30]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.strategies import AggregationStrategy, mixing_matrix
from repro.core.topology import barabasi_albert
from repro.data.pipeline import lm_token_stream
from repro.models.transformer import ForwardOptions, init_params
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=25)
ap.add_argument("--steps", type=int, default=8, help="steps per round")
ap.add_argument("--nodes", type=int, default=4)
ap.add_argument("--full100m", action="store_true",
                help="the full ~100M config (hours on CPU; the default "
                     "~8M config demonstrates the identical code path)")
args = ap.parse_args()

CFG = ModelConfig(
    name="llm-100m", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
    vocab_size=32768, mlp_kind="swiglu",
    dtype="float32", param_dtype="float32",
) if args.full100m else ModelConfig(
    name="llm-8m", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
    vocab_size=8192, mlp_kind="swiglu",
    dtype="float32", param_dtype="float32",
)
print(f"model: {CFG.param_count()/1e6:.0f}M params × {args.nodes} nodes")

pcfg = ParallelConfig(n_nodes=args.nodes, microbatch=1, remat=False)
topo = barabasi_albert(args.nodes, 2, seed=0)
coeffs = jnp.asarray(mixing_matrix(
    topo, AggregationStrategy("degree", tau=0.1),
))

opt = make_optimizer("adamw", 3e-4)
gossip_step = jax.jit(make_train_step(CFG, pcfg, opt,
                                      opts=ForwardOptions(remat=False)))
local_step = jax.jit(make_train_step(CFG, pcfg, opt,
                                     opts=ForwardOptions(remat=False),
                                     gossip=False))

one = init_params(jax.random.key(0), CFG)
params = jax.tree.map(
    lambda x: jnp.broadcast_to(x[None], (args.nodes,) + x.shape).copy(), one)
opt_state = jax.vmap(opt.init)(params)

streams = [lm_token_stream(CFG.vocab_size, seq_len=128, batch=2, seed=i)
           for i in range(args.nodes)]

for r in range(args.rounds):
    t0 = time.time()
    losses = []
    for s in range(args.steps):
        batch = {k: jnp.stack([next(st)[k] for st in streams])[:, None]
                 for k in ("tokens", "labels")}
        fn = gossip_step if s == args.steps - 1 else local_step
        params, opt_state, loss = fn(params, opt_state, batch, coeffs)
        losses.append(float(loss))
    print(f"round {r:3d}  loss {np.mean(losses):.4f}  "
          f"({time.time()-t0:.1f}s, {args.steps} steps × {args.nodes} nodes)")

print("\nDone: decentralized LM pre-training with Degree gossip "
      f"({args.rounds * args.steps} optimizer steps per node).")
