"""Pytest path setup: make `repro` (src layout) and `benchmarks` importable.

Deliberately does NOT touch XLA_FLAGS — tests must see the real single CPU
device; only launch/dryrun.py (and subprocess tests) force 512/8 devices.

Also opts the whole suite into strict NumPy-style rank checking
(``jax_numpy_rank_promotion="raise"``): implicit rank promotion is how a
``(n,)`` per-node vector silently broadcasts against an ``(n, n)``
coefficient matrix and turns a wrong axis into a wrong *number* instead
of an error.  Any code path that wants a broadcast states it explicitly
(``[:, None]`` / ``jnp.broadcast_to``).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import jax  # noqa: E402  (path setup must precede repro imports)

jax.config.update("jax_numpy_rank_promotion", "raise")

# the jaxlint fixture (repro.analysis.pytest_plugin) for all suites
pytest_plugins = ["repro.analysis.pytest_plugin"]
