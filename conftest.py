"""Pytest path setup: make `repro` (src layout) and `benchmarks` importable.

Deliberately does NOT touch XLA_FLAGS — tests must see the real single CPU
device; only launch/dryrun.py (and subprocess tests) force 512/8 devices.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
